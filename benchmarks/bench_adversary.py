"""V7 (beyond-paper): Byzantine robustness — attackers vs robust gossip.

Thin wrapper over the ``adversary`` sweep definition (one vmapped cell per
(aggregation rule × attacked-or-honest regime), attack type / seeds
batched), persisted to ``results/sweeps/adversary.json``.  The claim under
test: with f = ⌈n/8⌉ sign-flip attackers corrupting their outgoing round
deltas (``repro.core.adversary``), plain mean gossip diverges while the
robust aggregation lowerings (``mixing_impl=trimmed_mean`` /
``coord_median``) still reach ε — and cost nothing when every client is
honest.

``--smoke`` instead compiles and runs ONE Byzantine round step
(trimmed_mean under a sign-flip attacker) and checks two invariants on it:
an all-honest adversary extra is bit-identical to the no-adversary step,
and the robust aggregation matches the ``kernels.ref.robust_agg_ref``
oracle — the CI-sized proof that the adversary path works end to end.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.sweep import defs, run as sweep_run

from benchmarks.common import replicate_row

IMPLS = ["dense", "coord_median", "trimmed_mean"]
ROBUST = ("coord_median", "trimmed_mean")


def run(csv=print) -> dict:
    spec = defs.SWEEPS["adversary"]
    res = sweep_run.run_sweep(spec)
    pts = spec.points()
    f_levels = sorted({p["num_byzantine"] for p in pts})
    rows = {}
    for impl in IMPLS:
        for f in f_levels:
            # replicate groups aggregate over seeds only: attacked rows are
            # additionally keyed by the attack (f=0 pins attack="honest")
            attacks = sorted({p["attack"] for p in pts
                              if p["num_byzantine"] == f})
            for attack in attacks:
                row = replicate_row(res, mixing_impl=impl,
                                    num_byzantine=f, attack=attack)
                rows[f"{impl}/{attack}@f{f}"] = dict(
                    mixing_impl=impl, attack=attack, num_byzantine=f, **row)
                final = row["final_grad_mean"]
                csv(f"adversary,impl={impl},attack={attack},f={f},"
                    f"rounds={row['rounds_to_eps']},"
                    f"final_mean={final if final is None else round(final, 4)},"
                    f"hit_rate={row['hit_rate']}")
    # headline: structural selection (no label strings) — under the sneaky
    # sign-flip attack the robust rules must reach eps and plain gossip
    # must not
    f_max = max(f_levels)
    attacked = [r for r in rows.values() if r["num_byzantine"] == f_max
                and r["attack"] == "sign_flip"]
    robust_hit = all(r["hit_rate"] == 1.0 for r in attacked
                     if r["mixing_impl"] in ROBUST)
    dense_fails = all(r["hit_rate"] == 0.0 for r in attacked
                      if r["mixing_impl"] == "dense")
    honest = [r for r in rows.values() if r["num_byzantine"] == 0]
    honest_hit = all(r["hit_rate"] == 1.0 for r in honest)
    csv(f"adversary,summary,f={f_max},robust_hit={robust_hit},"
        f"dense_fails={dense_fails},honest_hit={honest_hit}")
    rows["_summary"] = {
        "num_byzantine": f_max,
        "robust_reaches_eps_under_sign_flip": robust_hit,
        "dense_fails_under_sign_flip": dense_fails,
        "all_honest_reach_eps": honest_hit,
        "byzantine_tolerated": robust_hit and dense_fails and honest_hit,
    }
    return rows


def smoke(n: int = 8) -> int:
    """Compile + run one Byzantine round step (trimmed_mean, one sign-flip
    attacker); exit 0 iff it runs, the honest clients stay finite, the
    all-honest adversary extra is bit-identical to the no-adversary step,
    and the robust reduce matches the oracle."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import AlgorithmConfig
    from repro.core import adversary as adversary_lib
    from repro.core import kgt_minimax as kgt
    from repro.core import mixing as mixing_lib
    from repro.core import objectives
    from repro.kernels import ref as ref_lib

    t0 = time.time()
    k_steps = 2
    data = objectives.make_quadratic_data(jax.random.PRNGKey(0), n, dx=8, dy=4)
    problem = objectives.quadratic_problem(data)
    algo = AlgorithmConfig(num_clients=n, local_steps=k_steps,
                           topology="full", mixing_impl="trimmed_mean",
                           eta_cx=0.05, eta_cy=0.05,
                           num_byzantine=1, attack="sign_flip",
                           attack_scale=3.0)
    key = jax.random.PRNGKey(1)
    batch1 = {k: v for k, v in data.items() if k != "mu"}
    batches = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (k_steps, *v.shape)), batch1)
    state = kgt.init_state(problem, algo, key, init_batch=batch1,
                           init_keys=jax.random.split(key, n))
    step = jax.jit(kgt.make_round_step(problem, algo, byzantine=True))
    keys = jax.random.split(key, k_steps * n).reshape(k_steps, n, 2)
    adv_fn = adversary_lib.make_attack_sampler(
        n, key, num_byzantine=algo.num_byzantine, attack=algo.attack,
        scale=algo.attack_scale)
    attacked = step(state, batches, keys, adv_fn(jnp.int32(0)))
    finite = all(bool(jnp.isfinite(leaf[1:]).all())
                 for leaf in jax.tree.leaves(attacked.x))

    honest_adv = adversary_lib.Adversary(
        ids=jnp.zeros((n,), jnp.int32), key=key, scale=jnp.float32(1.0))
    with_honest = step(state, batches, keys, honest_adv)
    plain = jax.jit(kgt.make_round_step(problem, algo))(state, batches, keys)
    identical = all(bool((a == b).all()) for a, b in zip(
        jax.tree.leaves(with_honest), jax.tree.leaves(plain)))

    vals = jax.random.normal(jax.random.PRNGKey(2), (n, n, 16))
    valid = jnp.ones((n, n), bool)
    diff = float(jnp.abs(
        mixing_lib._robust_reduce(vals, valid, "trimmed_mean", 1)
        - ref_lib.robust_agg_ref(vals, valid, rule="trimmed_mean", trim=1)
    ).max())
    ok = finite and identical and diff == 0.0
    print(f"[adversary-smoke] byzantine trimmed_mean round at n={n}: "
          f"honest_finite={finite} honest_extra_bit_identical={identical} "
          f"oracle_diff={diff:.1e} "
          f"({'ok' if ok else 'FAILED'}, {time.time() - t0:.1f}s)",
          flush=True)
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="compile + one Byzantine trimmed_mean round at n=8")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke())
    run()


if __name__ == "__main__":
    main()
