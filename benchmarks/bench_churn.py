"""V6 (beyond-paper): robustness to churn — time-varying random topologies
and partial client participation.

Thin wrapper over the ``churn`` sweep definition (one vmapped cell per
(topology family × participation regime), edge probability / participation
rate / seeds batched), persisted to ``results/sweeps/churn.json``.  The
claim under test: gradient tracking keeps converging when the gossip
matrix is redrawn every round (Erdős–Rényi, random pairwise) or clients
drop out (dropout family, Bernoulli participation) — the degradation
relative to the static full-participation cell is the reported number.
"""
from __future__ import annotations

from repro.sweep import defs, run as sweep_run

from benchmarks.common import replicate_row

FAMILIES = ["static", "erdos_renyi", "pairwise", "dropout"]


def static_baseline(rows: dict) -> dict:
    """The static-topology full-participation row, selected by its fields.

    Selection must be structural, not by display label: the label embeds
    ``edge_prob`` whenever the family has more than one, so a key like
    ``"static@1.0"`` silently stops existing when the grid changes and the
    headline comparison crashes (or worse, picks up a stale row from a
    previously merged store).
    """
    cands = [r for r in rows.values() if isinstance(r, dict)
             and r.get("topology_family") == "static"]
    if not cands:
        raise KeyError("churn rows contain no static-topology row")
    return max(cands, key=lambda r: r["participation"])


def run(csv=print):
    spec = defs.SWEEPS["churn"]
    res = sweep_run.run_sweep(spec)
    pts = spec.points()
    rows = {}
    for family in FAMILIES:
        # replicate groups must only aggregate over seeds: erdos_renyi rows
        # are additionally keyed by edge_prob (the other families pin it)
        edge_probs = sorted({p["edge_prob"] for p in pts
                             if p["topology_family"] == family})
        for rate in sorted({p["participation"] for p in pts}, reverse=True):
            for ep in edge_probs:
                row = replicate_row(res, topology_family=family,
                                    participation=rate, edge_prob=ep)
                label = (f"{family}(edge_prob={ep})"
                         if len(edge_probs) > 1 else family)
                rows[f"{label}@{rate}"] = dict(topology_family=family,
                                               participation=rate,
                                               edge_prob=ep, **row)
                csv(f"churn,{label},participation={rate},"
                    f"rounds={row['rounds_to_eps']},"
                    f"final={row['final_grad']:.4f},"
                    f"final_mean={row['final_grad_mean']:.4f},"
                    f"hit_rate={row['hit_rate']}")
    # headline: worst-case degradation of the tracked variant under churn
    static_full = static_baseline(rows)["final_grad_mean"]
    worst = max(r["final_grad_mean"] for r in rows.values())
    csv(f"churn,summary,static_full={static_full:.4f},worst={worst:.4f}")
    rows["_summary"] = {"static_full_final_mean": static_full,
                        "worst_final_mean": worst}
    return rows
