"""Table-1 proxy: rounds-to-ε for K-GT-Minimax vs the baseline family on the
same heterogeneous NC-SC problem (paper claim: decentralized + local updates
+ heterogeneity robustness simultaneously).

Runs through the ``repro.engine`` chunked scan — one compiled program per
evaluation interval, ∇Φ checked on the chunk-boundary state (the same
rounds-to-ε grid as the historical per-round loop; see
``benchmarks.common.run_to_epsilon``)."""
from __future__ import annotations

from benchmarks.common import run_to_epsilon

ALGOS = ["kgt_minimax", "gt_gda", "dsgda", "local_sgda"]


def run(csv=print):
    rows = {}
    for algo in ALGOS:
        hit, final, wall, _ = run_to_epsilon(
            algorithm=algo, n=8, K=8, sigma=0.1, heterogeneity=2.0, eps=0.3,
            eta_cx=0.01, eta_cy=0.1,
            eta_s=0.5 if algo in ("kgt_minimax", "gt_gda") else 1.0,
            max_rounds=1500)
        rows[algo] = dict(rounds_to_eps=hit, final_grad=final, wall_s=round(wall, 1))
        csv(f"convergence,{algo},rounds_to_eps={hit},final_grad={final:.4f}")
    return rows
