"""Table-1 proxy: rounds-to-ε for K-GT-Minimax vs the baseline family on the
same heterogeneous NC-SC problem (paper claim: decentralized + local updates
+ heterogeneity robustness simultaneously).

Thin wrapper over the ``convergence`` sweep definition — now
seed-replicated: each algorithm is one vmapped cell of 8 seeds, so the
comparison carries mean±std error bars instead of a single trajectory.
Persisted to ``results/sweeps/convergence.json``.
"""
from __future__ import annotations

from repro.sweep import defs, run as sweep_run

from benchmarks.common import replicate_row

ALGOS = ["kgt_minimax", "gt_gda", "dsgda", "local_sgda"]


def run(csv=print):
    res = sweep_run.run_sweep(defs.SWEEPS["convergence"])
    rows = {}
    for algo in ALGOS:
        row = replicate_row(res, algorithm=algo)
        cell = res["cells"].get(f"algorithm={algo}", {})
        rows[algo] = dict(row, compile_s=cell.get("compile_s"),
                          run_s=cell.get("run_s"))
        csv(f"convergence,{algo},rounds_to_eps={row['rounds_to_eps']},"
            f"final_grad={row['final_grad']:.4f}"
            f",rounds_mean={row['rounds_to_eps_mean']}"
            f",hit_rate={row['hit_rate']:.2f}")
    return rows
