"""Engine throughput benchmark: per-round host dispatch vs scanned chunks.

Measures rounds/s of the same K-GT-Minimax training program under the two
execution models ``repro.launch.train`` exposes:

  * ``host``  — the historical loop: sample a batch (jitted, but dispatched
    per round), feed it to one jitted ``round_step``; host dispatch +
    per-round Python overhead paid every round.
  * ``scan``  — the ``repro.engine`` model: ``chunk`` rounds compiled as a
    single ``lax.scan`` program with the sampler inlined on device; the
    host pays one dispatch per chunk.

Two workloads, two regimes:

  * ``toy`` — the paper's toy experiment: the synthetic heterogeneous NC-SC
    quadratic (``benchmarks.common`` geometry, n=8, K=8).  Per-round
    compute is microseconds, so the thousands-of-rounds trajectories the
    paper's Table-1/V1–V6 comparisons run are *dispatch-bound* — exactly
    what the scan amortizes.  This is the headline ``speedup_chunk16``.
  * ``dro_lm`` — reduced paper-toy LM DRO training.  Per-round compute is
    hundreds of ms on this CPU, so dispatch is already hidden by async
    dispatch pipelining and the scan can only tie; reported to show the
    engine costs nothing when compute-bound (on fast accelerators the LM
    rounds shrink back toward the dispatch-bound regime).

The trajectories are bit-identical (tests/test_engine.py); this benchmark
only times them.  CSV rows: ``engine,workload=...,mode=...,rounds_per_s=...``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import engine as engine_lib
from repro.configs import registry
from repro.configs.base import AlgorithmConfig
from repro.core import kgt_minimax as kgt
from repro.core import objectives
from repro.data import synthetic as data_lib
from repro.core import make_quadratic_data, quadratic_problem

TOY_ROUNDS = 512
LM_ROUNDS = 32
CHUNKS = (1, 4, 16)


def _toy_setup():
    """The paper's synthetic NC-SC quadratic (same geometry as
    benchmarks.common / examples/quickstart.py)."""
    n, K = 8, 8
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, n, dx=10, dy=5, heterogeneity=2.0)
    problem = quadratic_problem(data, sigma=0.1)
    algo = AlgorithmConfig(num_clients=n, local_steps=K, eta_cx=0.01,
                           eta_cy=0.1, eta_sx=0.5, eta_sy=0.5, topology="ring")
    cb = {k: v for k, v in data.items() if k != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), cb)
    state = kgt.init_state(problem, algo, key, init_batch=cb,
                           init_keys=jax.random.split(key, n))
    sampler = engine_lib.make_fixed_batch_sampler(
        kb, local_steps=K, num_clients=n, seed=0)
    return state, kgt.make_round_step(problem, algo), sampler


def _lm_setup():
    """Reduced paper-toy LM under DRO (what launch/train runs on CPU)."""
    n, K, batch, seq, groups = 4, 2, 2, 32, 4
    cfg = registry.reduced(registry.get_model_config("paper-toy"))
    algo = AlgorithmConfig(num_clients=n, local_steps=K, eta_cx=0.02,
                           eta_cy=0.2, eta_sx=0.7, eta_sy=0.7, topology="ring")
    key = jax.random.PRNGKey(0)
    kd, ki, kt = jax.random.split(key, 3)
    dm = data_lib.make_data_model(kd, vocab_size=cfg.vocab_size,
                                  num_groups=groups, num_clients=n)
    problem = objectives.dro_problem(cfg, num_groups=groups, mu=1.0)
    sampler = engine_lib.make_dro_sampler(
        dm, kt, local_steps=K, num_clients=n, per_client_batch=batch,
        seq_len=seq, cfg=cfg)
    init_b, _ = sampler(jnp.int32(0))
    state = kgt.init_state(problem, algo, ki,
                           init_batch=jax.tree.map(lambda x: x[0], init_b),
                           init_keys=jax.random.split(ki, n))
    return state, kgt.make_round_step(problem, algo), sampler


def _block(state):
    jax.block_until_ready(jax.tree.leaves(state.x)[0])


def _time_host(state, round_step, sampler, rounds: int, reps: int) -> float:
    """Per-round dispatch: jitted sampler + jitted step, host loop.
    Best-of-``reps`` (this container's CPU is noisy/shared)."""
    sample = jax.jit(sampler)
    step = jax.jit(round_step)
    b, k = sample(jnp.int32(0))
    state = step(state, b, k)  # compile both programs
    _block(state)
    best = 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        for t in range(1, rounds + 1):
            b, k = sample(jnp.int32(t))
            state = step(state, b, k)
        _block(state)
        best = max(best, rounds / (time.perf_counter() - t0))
    return best


def _time_scan(state, round_step, sampler, rounds: int, chunk: int,
               reps: int) -> float:
    """Scanned chunks: one dispatch per ``chunk`` rounds (no metrics, like
    the host loop between log points), state donated across chunks exactly
    as ``engine.run`` does.  Best-of-``reps``."""
    build = engine_lib.make_chunk_builder(round_step, sampler, None)
    fn = build(chunk)
    # donation consumes the caller's buffers — work on a private copy
    state = jax.tree.map(lambda x: x.copy(), state)
    final = jnp.int32(10**9)
    state, _ = fn(state, final)  # compile
    _block(state)
    timed = (rounds // chunk) * chunk  # rounds actually executed per rep
    best = 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(rounds // chunk):
            state, _ = fn(state, final)
        _block(state)
        best = max(best, timed / (time.perf_counter() - t0))
    return best


def _bench_workload(name, setup, rounds, chunks, csv, results, reps=3):
    state, round_step, sampler = setup()
    rps_host = _time_host(state, round_step, sampler, rounds, reps)
    csv(f"engine,workload={name},mode=host,rounds_per_s={rps_host:.2f}")
    wl = {"host_rounds_per_s": round(rps_host, 3), "timed_rounds": rounds}
    for chunk in chunks:
        rps = _time_scan(state, round_step, sampler, rounds, chunk, reps)
        csv(f"engine,workload={name},mode=scan,chunk={chunk},"
            f"rounds_per_s={rps:.2f},speedup={rps / rps_host:.2f}x")
        wl[f"scan_chunk{chunk}"] = {
            "rounds_per_s": round(rps, 3),
            "speedup_vs_host": round(rps / rps_host, 3),
        }
    results[name] = wl
    return wl


def run(csv=print) -> dict:
    results: dict = {}
    toy = _bench_workload("toy", _toy_setup, TOY_ROUNDS, CHUNKS, csv, results)
    lm = _bench_workload("dro_lm", _lm_setup, LM_ROUNDS, (1, 16), csv,
                         results, reps=2)
    # headline: the paper-regime (dispatch-bound many-round) speedup
    results["speedup_chunk16"] = toy["scan_chunk16"]["speedup_vs_host"]
    results["speedup_chunk16_lm"] = lm["scan_chunk16"]["speedup_vs_host"]
    csv(f"engine,summary=speedup_chunk16,toy={results['speedup_chunk16']}x,"
        f"dro_lm={results['speedup_chunk16_lm']}x")
    return results
