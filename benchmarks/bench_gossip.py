"""Round-epilogue microbenchmark: per-leaf dense vs fused vs pallas_packed.

The gossip/correction/parameter-mixing epilogue (Algorithm 1 lines 7–11) is
the per-round communication cost the paper optimizes.  This benchmark
compares the three lowerings over a synthetic transformer-shaped client
state:

  * wall time of the jitted epilogue on this host (`pallas_packed` runs the
    packed-xla oracle; `pallas_packed_interpret` runs the actual Pallas
    kernel through the interpreter — kernel validation, not a speed claim);
  * cross-client collective launches + bytes in the compiled HLO on a
    4-fake-CPU-device clients mesh.  This runs in a subprocess because the
    XLA host-device-count flag must precede jax's first backend init.

CSV rows: ``gossip,impl=...,wall_ms=...`` and ``gossip,impl=...,collectives=...``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mixing as mixing_lib
from repro.core import packing, topology
from repro.core.kgt_minimax import _tree_axpy, _tree_sub
from repro.kernels import ops as kernel_ops

N_CLIENTS = 8
ETA_S, CORR = 0.5, 12.5  # η_s and 1/(K·η_c) stand-ins
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def synthetic_state(n: int = N_CLIENTS, d_model: int = 64, layers: int = 2,
                    seed: int = 0):
    """Client-stacked transformer-shaped pytree (many leaves, ragged sizes)."""
    key = jax.random.PRNGKey(seed)
    tree = {}
    for i in range(layers):
        key, *ks = jax.random.split(key, 8)
        tree[f"layer{i}"] = {
            "q": jax.random.normal(ks[0], (n, d_model, d_model)),
            "k": jax.random.normal(ks[1], (n, d_model, d_model)),
            "v": jax.random.normal(ks[2], (n, d_model, d_model)),
            "o": jax.random.normal(ks[3], (n, d_model, d_model)),
            "up": jax.random.normal(ks[4], (n, d_model, 4 * d_model)),
            "down": jax.random.normal(ks[5], (n, 4 * d_model, d_model)),
            "norm": jax.random.normal(ks[6], (n, d_model)),
        }
    return tree


def epilogue_per_leaf(w, fused: bool):
    """The per-leaf lowering of kgt_minimax.round_step: one (dense) or half
    (fused: Δ and θ stacked into one collective) gossip launches per leaf,
    then the per-leaf correction/mixing axpy cascade."""

    def fn(dx, x, cx):
        if fused:
            pairs = jax.tree.map(lambda d, b: jnp.stack([d, b], axis=1), dx, x)
            mixed = mixing_lib.mix_dense(pairs, w)
            mdx = jax.tree.map(lambda p: p[:, 0], mixed)
            mx = jax.tree.map(lambda p: p[:, 1], mixed)
        else:
            mdx = mixing_lib.mix_dense(dx, w)
            mx = mixing_lib.mix_dense(x, w)
        cx_new = _tree_axpy(CORR, _tree_sub(dx, mdx), cx)
        x_new = _tree_axpy(ETA_S, mdx, mx)
        return x_new, cx_new

    return fn


def epilogue_packed(w, backend: str):
    """The fused-gossip round engine: ravel, one fused pass, unravel."""

    def fn(dx, x, cx):
        spec = packing.pack_spec(x)
        spec_c = packing.pack_spec(cx)
        xb, cb = kernel_ops.fused_gossip_round(
            w, packing.pack(dx, spec), packing.pack(x, spec),
            packing.pack(cx, spec_c), ETA_S, CORR, backend=backend)
        return packing.unpack(xb, spec), packing.unpack(cb, spec_c)

    return fn


EPILOGUES = {
    "dense": lambda w: epilogue_per_leaf(w, fused=False),
    "fused": lambda w: epilogue_per_leaf(w, fused=True),
    "pallas_packed": lambda w: epilogue_packed(w, "xla"),
    "pallas_packed_interpret": lambda w: epilogue_packed(w, "interpret"),
}


def _time_ms(fn, args, reps: int) -> float:
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def collective_counts_child() -> None:
    """Child mode (fake 4-device mesh already forced via XLA_FLAGS): compile
    each epilogue with the clients dim mesh-sharded and count collectives."""
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.analysis import hlo_cost

    n = 4
    mesh = Mesh(np.array(jax.devices()[:n]), ("clients",))
    w = jnp.asarray(topology.mixing_matrix("exp", n), jnp.float32)
    x = synthetic_state(n=n, d_model=16, layers=2)
    dx = jax.tree.map(lambda v: v * 0.01, x)
    cx = jax.tree.map(jnp.zeros_like, x)
    shard = jax.tree.map(lambda v: NamedSharding(mesh, P("clients")), x)

    out = {}
    for name in ("dense", "fused", "pallas_packed"):
        fn = jax.jit(EPILOGUES[name](w), in_shardings=(shard, shard, shard))
        txt = fn.lower(dx, x, cx).compile().as_text()
        cost = hlo_cost.analyze(txt)
        out[name] = {
            "collectives": int(sum(cost.collective_counts.values())),
            "by_kind": {k: int(v) for k, v in cost.collective_counts.items()
                        if v},
            "collective_mb": round(cost.total_collective_bytes() / 1e6, 3),
        }
    print("JSON:" + json.dumps(out), flush=True)


def _collectives_via_subprocess() -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_gossip", "--collectives-child"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(f"collectives child failed:\n{proc.stdout[-2000:]}"
                           f"\n{proc.stderr[-2000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith("JSON:"):
            return json.loads(line[len("JSON:"):])
    raise RuntimeError(f"no JSON line in child output: {proc.stdout[-500:]}")


def run(csv=print) -> dict:
    w = jnp.asarray(topology.mixing_matrix("exp", N_CLIENTS), jnp.float32)
    x = synthetic_state()
    dx = jax.tree.map(lambda v: v * 0.01, x)
    cx = jax.tree.map(jnp.zeros_like, x)
    spec = packing.pack_spec(x)
    results: dict = {"n": N_CLIENTS, "leaves": len(jax.tree.leaves(x)),
                     "packed_D": spec.dim}

    for name, builder in EPILOGUES.items():
        reps = 2 if name.endswith("interpret") else 20
        ms = _time_ms(jax.jit(builder(w)), (dx, x, cx), reps)
        csv(f"gossip,impl={name},wall_ms={ms:.2f},n={N_CLIENTS},"
            f"leaves={results['leaves']},packed_D={spec.dim}")
        results[name] = {"wall_ms": round(ms, 3)}

    for name, c in _collectives_via_subprocess().items():
        kinds = ";".join(f"{k}:{v}" for k, v in sorted(c["by_kind"].items()))
        csv(f"gossip,impl={name},collectives={c['collectives']},"
            f"collective_mb={c['collective_mb']},kinds={kinds}")
        results.setdefault(name, {}).update(c)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--collectives-child", action="store_true")
    args = ap.parse_args()
    if args.collectives_child:
        collective_counts_child()
    else:
        run()


if __name__ == "__main__":
    main()
