"""Round-lowering microbenchmark: epilogue lowerings + the whole-round kernel.

Two workloads, one claim — "the Pallas path is the fastest way to run a
round on this host":

* **Round rows** — the timed comparison (``wall_ms``, one workload so the
  rows are comparable): the whole round (K local SGDA steps AND the
  epilogue) on the quadratic workload (dx=384/dy=128/K=8), one row per
  lowering of ``make_round_step``.  ``dense_round`` is the per-leaf
  baseline (autodiff gradients, ~2× the flops of the affine form, one
  scan over K); ``pallas_packed_round`` swaps in the packed epilogue but
  keeps the scanned local steps; ``fused_round`` is the whole-round
  kernel of ``kernels/fused_round.py`` (K affine steps fused with the
  gossip matmuls — the lowering the ROADMAP's open item 2 asked for);
  ``fused_round_int8`` adds error-feedback int8-compressed gossip on top
  (what a real wire saves 4× on, ``core.compression``).
  ``fastest_timed`` is computed over these rows — the acceptance claim is
  that ``fused_round`` wins it, strictly under ``dense_round``.

* **Epilogue rows** (transformer-shaped state, many ragged leaves,
  ``epilogue_ms`` — deliberately NOT ``wall_ms``: an epilogue-only time
  on a different state is not comparable with a whole-round time): the
  gossip/correction/parameter-mixing epilogue of Algorithm 1 lines 7–11,
  lowered per-leaf (``dense``/``fused``), whole-state packed
  (``pallas_packed`` — the packed-xla oracle on this host), and sparse
  neighbor-gather (``sparse_packed``).  Each row also reports achieved
  HBM bandwidth (the epilogue moves 5·n·D·4 bytes: read Δ, θ, c; write
  θ', c') as a fraction of ``benchmarks.roofline.HBM_BW``.
  ``pallas_packed_interpret`` — the actual Pallas kernel through the
  interpreter — is a *parity/smoke* row only: it validates the kernel
  against the oracle but its wall time measures the interpreter, so it
  stays out of both comparisons.

Also: a one-time ``block_d`` autotune for the epilogue kernel — sweeps
``kernels.ops.BLOCK_D_CANDIDATES`` for this (n, D), records the winner via
``ops.record_block_d`` (so ``fused_gossip_round(block_d=None)`` defaults to
it), and reports the sweep in the bench row.  On this CPU host the sweep
times the interpreter (relative block costs, not kernel truth); on a TPU the
same sweep times the compiled kernel.

Collective counts/bytes per lowering come from a 4-fake-CPU-device clients
mesh in a subprocess (the XLA host-device-count flag must precede jax's
first backend init).  ``--smoke`` skips the subprocess and the autotune.

CSV rows: ``gossip,impl=*_round,wall_ms=...``,
``gossip,impl=...,epilogue_ms=...,gbs=...,hbm_frac=...``,
``gossip,autotune,...``, ``gossip,impl=...,collectives=...``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.roofline import HBM_BW
from repro.configs.base import AlgorithmConfig
from repro.core import mixing as mixing_lib
from repro.core import objectives, packing, topology
from repro.core import sparse_topology as sparse_lib
from repro.core.kgt_minimax import _tree_axpy, _tree_sub, init_state, \
    make_round_step
from repro.kernels import ops as kernel_ops

N_CLIENTS = 8
ETA_S, CORR = 0.5, 12.5  # η_s and 1/(K·η_c) stand-ins
# round-rows quadratic geometry: big enough that the K local steps dominate
ROUND_DX, ROUND_DY, ROUND_K = 384, 128, 8
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def synthetic_state(n: int = N_CLIENTS, d_model: int = 64, layers: int = 2,
                    seed: int = 0):
    """Client-stacked transformer-shaped pytree (many leaves, ragged sizes)."""
    key = jax.random.PRNGKey(seed)
    tree = {}
    for i in range(layers):
        key, *ks = jax.random.split(key, 8)
        tree[f"layer{i}"] = {
            "q": jax.random.normal(ks[0], (n, d_model, d_model)),
            "k": jax.random.normal(ks[1], (n, d_model, d_model)),
            "v": jax.random.normal(ks[2], (n, d_model, d_model)),
            "o": jax.random.normal(ks[3], (n, d_model, d_model)),
            "up": jax.random.normal(ks[4], (n, d_model, 4 * d_model)),
            "down": jax.random.normal(ks[5], (n, 4 * d_model, d_model)),
            "norm": jax.random.normal(ks[6], (n, d_model)),
        }
    return tree


def epilogue_per_leaf(w, fused: bool):
    """The per-leaf lowering of kgt_minimax.round_step: one (dense) or half
    (fused: Δ and θ stacked into one collective) gossip launches per leaf,
    then the per-leaf correction/mixing axpy cascade."""

    def fn(dx, x, cx):
        if fused:
            pairs = jax.tree.map(lambda d, b: jnp.stack([d, b], axis=1), dx, x)
            mixed = mixing_lib.mix_dense(pairs, w)
            mdx = jax.tree.map(lambda p: p[:, 0], mixed)
            mx = jax.tree.map(lambda p: p[:, 1], mixed)
        else:
            mdx = mixing_lib.mix_dense(dx, w)
            mx = mixing_lib.mix_dense(x, w)
        cx_new = _tree_axpy(CORR, _tree_sub(dx, mdx), cx)
        x_new = _tree_axpy(ETA_S, mdx, mx)
        return x_new, cx_new

    return fn


def epilogue_packed(w, backend: str, block_d=None):
    """The fused-gossip round engine: ravel, one fused pass, unravel."""

    def fn(dx, x, cx):
        spec = packing.pack_spec(x)
        spec_c = packing.pack_spec(cx)
        xb, cb = kernel_ops.fused_gossip_round(
            w, packing.pack(dx, spec), packing.pack(x, spec),
            packing.pack(cx, spec_c), ETA_S, CORR, backend=backend,
            block_d=block_d)
        return packing.unpack(xb, spec), packing.unpack(cb, spec_c)

    return fn


def epilogue_sparse(w, backend: str):
    """Neighbor-gather lowering: same packed epilogue, W as padded-CSR."""
    sp = sparse_lib.from_dense(np.asarray(w))

    def fn(dx, x, cx):
        spec = packing.pack_spec(x)
        spec_c = packing.pack_spec(cx)
        xb, cb = kernel_ops.sparse_gossip_round(
            sp.neighbor_idx, sp.neighbor_w, sp.self_w,
            packing.pack(dx, spec), packing.pack(x, spec),
            packing.pack(cx, spec_c), ETA_S, CORR, backend=backend)
        return packing.unpack(xb, spec), packing.unpack(cb, spec_c)

    return fn


# Epilogue-only comparison (epilogue_ms); the interpret row is parity-only.
EPILOGUES = {
    "dense": lambda w: epilogue_per_leaf(w, fused=False),
    "fused": lambda w: epilogue_per_leaf(w, fused=True),
    "pallas_packed": lambda w: epilogue_packed(w, "xla"),
    "sparse_packed": lambda w: epilogue_sparse(w, "xla"),
}


def _time_ms(fn, args, reps: int) -> float:
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def _round_step_fn(impl: str, compress=None, seed: int = 0):
    """Whole-round program on the quadratic workload + its operands."""
    n, k = N_CLIENTS, ROUND_K
    key = jax.random.PRNGKey(seed)
    data = objectives.make_quadratic_data(key, n, dx=ROUND_DX, dy=ROUND_DY,
                                          heterogeneity=1.0)
    problem = objectives.quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(
        algorithm="kgt_minimax", num_clients=n, local_steps=k,
        eta_cx=0.01, eta_cy=0.05, topology="exp", mixing_impl=impl,
        gossip_backend="xla", gossip_compress=compress)
    batch = {key_: data[key_] for key_ in ("A", "B", "b", "q")}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)),
                      batch)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), k * n).reshape(
        k, n, 2).astype(jnp.uint32)
    st = init_state(problem, cfg, key, init_batch=batch, init_keys=keys[0])
    step = jax.jit(make_round_step(problem, cfg))
    return step, (st, kb, keys)


def _autotune_block_d(w, dx, x, cx, csv, results: dict) -> None:
    """One-time block_d sweep for the epilogue kernel at this (n, D):
    record the winner so ``fused_gossip_round(block_d=None)`` defaults to
    the measured best instead of the hardcoded 512."""
    spec = packing.pack_spec(x)
    n, d = spec.n, spec.dim
    sweep = {}
    for blk in kernel_ops.BLOCK_D_CANDIDATES:
        fn = jax.jit(epilogue_packed(w, "interpret", block_d=blk))
        sweep[blk] = _time_ms(fn, (dx, x, cx), reps=1)
    best = min(sweep, key=sweep.get)
    kernel_ops.record_block_d(n, d, best)
    csv("gossip,autotune,block_d=" + str(best) + ","
        + ",".join(f"ms_{b}={m:.1f}" for b, m in sorted(sweep.items())))
    results["autotune"] = {"n": n, "packed_D": d, "best_block_d": best,
                           "sweep_ms": {str(b): round(m, 2)
                                        for b, m in sweep.items()}}


def collective_counts_child() -> None:
    """Child mode (fake 4-device mesh already forced via XLA_FLAGS): compile
    each epilogue with the clients dim mesh-sharded and count collectives."""
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.analysis import hlo_cost

    n = 4
    mesh = Mesh(np.array(jax.devices()[:n]), ("clients",))
    w = jnp.asarray(topology.mixing_matrix("exp", n), jnp.float32)
    x = synthetic_state(n=n, d_model=16, layers=2)
    dx = jax.tree.map(lambda v: v * 0.01, x)
    cx = jax.tree.map(jnp.zeros_like, x)
    shard = jax.tree.map(lambda v: NamedSharding(mesh, P("clients")), x)

    out = {}
    for name in ("dense", "fused", "pallas_packed"):
        fn = jax.jit(EPILOGUES[name](w), in_shardings=(shard, shard, shard))
        txt = fn.lower(dx, x, cx).compile().as_text()
        cost = hlo_cost.analyze(txt)
        out[name] = {
            "collectives": int(sum(cost.collective_counts.values())),
            "by_kind": {k: int(v) for k, v in cost.collective_counts.items()
                        if v},
            "collective_mb": round(cost.total_collective_bytes() / 1e6, 3),
        }
    print("JSON:" + json.dumps(out), flush=True)


def _collectives_via_subprocess() -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_gossip", "--collectives-child"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(f"collectives child failed:\n{proc.stdout[-2000:]}"
                           f"\n{proc.stderr[-2000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith("JSON:"):
            return json.loads(line[len("JSON:"):])
    raise RuntimeError(f"no JSON line in child output: {proc.stdout[-500:]}")


def run(csv=print, smoke: bool = False) -> dict:
    w = jnp.asarray(topology.mixing_matrix("exp", N_CLIENTS), jnp.float32)
    x = synthetic_state()
    dx = jax.tree.map(lambda v: v * 0.01, x)
    cx = jax.tree.map(jnp.zeros_like, x)
    spec = packing.pack_spec(x)
    results: dict = {"n": N_CLIENTS, "leaves": len(jax.tree.leaves(x)),
                     "packed_D": spec.dim}
    # what the epilogue moves through memory: read Δ, θ, c; write θ', c'
    epilogue_bytes = 5 * spec.n * spec.dim * 4

    if not smoke:
        _autotune_block_d(w, dx, x, cx, csv, results)

    for name, builder in EPILOGUES.items():
        reps = 2 if smoke else 20
        ms = _time_ms(jax.jit(builder(w)), (dx, x, cx), reps)
        gbs = epilogue_bytes / (ms / 1e3) / 1e9
        frac = gbs / (HBM_BW / 1e9)
        csv(f"gossip,impl={name},epilogue_ms={ms:.2f},gbs={gbs:.1f},"
            f"hbm_frac={frac:.3f},n={N_CLIENTS},"
            f"leaves={results['leaves']},packed_D={spec.dim}")
        results[name] = {"epilogue_ms": round(ms, 3),
                         "achieved_gbs": round(gbs, 2),
                         "hbm_frac": round(frac, 4)}

    # Pallas-kernel parity (interpret mode): validation, never a speed row —
    # the interpreter's wall time says nothing about the compiled kernel.
    ref = jax.jit(EPILOGUES["pallas_packed"](w))(dx, x, cx)
    got = jax.jit(epilogue_packed(w, "interpret"))(dx, x, cx)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)))
    csv(f"gossip,impl=pallas_packed_interpret,parity_max_err={err:.2e},"
        f"parity_ok={int(err <= 1e-6)}")
    results["pallas_packed_interpret"] = {
        "parity_max_err": err, "parity_ok": bool(err <= 1e-6)}
    if err > 1e-6:
        raise AssertionError(
            f"pallas_packed interpret/xla parity broke: max err {err:.3e}")

    # Whole-round rows: K local steps + epilogue, quadratic workload.
    round_rows = [("dense_round", "dense", None),
                  ("pallas_packed_round", "pallas_packed", None),
                  ("fused_round", "fused_round", None),
                  ("fused_round_int8", "fused_round", "int8")]
    for row, impl, compress in round_rows:
        step, (st, kb, keys) = _round_step_fn(impl, compress)
        ms = _time_ms(step, (st, kb, keys), 2 if smoke else 20)
        csv(f"gossip,impl={row},wall_ms={ms:.2f},workload=quadratic,"
            f"dz={ROUND_DX + ROUND_DY},K={ROUND_K},n={N_CLIENTS}")
        results[row] = {"wall_ms": round(ms, 3), "workload": "quadratic",
                        "dz": ROUND_DX + ROUND_DY, "K": ROUND_K}
        if compress:
            from repro.kernels.quantize import wire_bits
            results[row]["wire_bits"] = wire_bits(compress)

    timed = [k for k in results
             if isinstance(results[k], dict) and "wall_ms" in results[k]]
    fastest = min(timed, key=lambda k: results[k]["wall_ms"])
    results["fastest_timed"] = fastest
    csv(f"gossip,fastest_timed={fastest},"
        f"wall_ms={results[fastest]['wall_ms']}")

    if not smoke:
        for name, c in _collectives_via_subprocess().items():
            kinds = ";".join(f"{k}:{v}" for k, v in sorted(c["by_kind"].items()))
            csv(f"gossip,impl={name},collectives={c['collectives']},"
                f"collective_mb={c['collective_mb']},kinds={kinds}")
            results.setdefault(name, {}).update(c)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--collectives-child", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: fewer reps, skip the collectives "
                         "subprocess and the block_d autotune")
    args = ap.parse_args()
    if args.collectives_child:
        collective_counts_child()
    else:
        run(smoke=args.smoke)


if __name__ == "__main__":
    main()
