"""V3: heterogeneity robustness — K-GT-Minimax's convergence is flat in the
inter-client heterogeneity level; local SGDA (no tracking) degrades (the DH
column of Table 1)."""
from __future__ import annotations

from benchmarks.common import run_to_epsilon

LEVELS = [0.0, 1.0, 2.0, 4.0]


def run(csv=print):
    rows = {}
    for het in LEVELS:
        row = {}
        for algo in ("kgt_minimax", "local_sgda"):
            hit, final, _, _ = run_to_epsilon(
                algorithm=algo, heterogeneity=het, n=8, K=8, sigma=0.0,
                eps=0.2, eta_cx=0.01, eta_cy=0.1,
                eta_s=0.5 if algo == "kgt_minimax" else 1.0, max_rounds=1200)
            row[algo] = dict(rounds_to_eps=hit, final_grad=final)
            csv(f"heterogeneity,het={het},{algo},rounds={hit},final={final:.4f}")
        rows[het] = row
    return rows
