"""V3: heterogeneity robustness — K-GT-Minimax's convergence is flat in the
inter-client heterogeneity level; local SGDA (no tracking) degrades (the DH
column of Table 1).

Thin wrapper over the ``heterogeneity`` sweep definition: one vmapped cell
per algorithm (heterogeneity levels × seeds batched — heterogeneity only
shapes the data arrays, so it rides the trajectory axis), persisted to
``results/sweeps/heterogeneity.json``.
"""
from __future__ import annotations

from repro.sweep import defs, run as sweep_run

from benchmarks.common import replicate_row

LEVELS = [0.0, 1.0, 2.0, 4.0]


def run(csv=print):
    res = sweep_run.run_sweep(defs.SWEEPS["heterogeneity"])
    rows = {}
    for het in LEVELS:
        row = {}
        for algo in ("kgt_minimax", "local_sgda"):
            row[algo] = replicate_row(res, heterogeneity=het, algorithm=algo)
            csv(f"heterogeneity,het={het},{algo},"
                f"rounds={row[algo]['rounds_to_eps']},"
                f"final={row[algo]['final_grad']:.4f}"
                f",rounds_mean={row[algo]['rounds_to_eps_mean']}")
        rows[het] = row
    return rows
