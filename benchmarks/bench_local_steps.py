"""V2: local updates amortize gradient noise — Theorem 1's σ²/(nK ε⁴) term.

With the theory-prescribed stepsizes (η_c ∝ 1/K for stability), the
per-round update averages K stochastic gradients, so at a fixed round budget
in the noise-dominated regime the stationarity floor improves with K
(equivalently: rounds-to-ε for noise-limited ε falls with K — communication
efficiency).  We report the final ‖∇Φ(x̄)‖ after a fixed 400 rounds under
strong noise (σ=2), plus rounds-to-ε at a noise-limited target.
"""
from __future__ import annotations

from benchmarks.common import run_to_epsilon

KS = [1, 2, 4, 8, 16]


def run(csv=print):
    rows = {}
    for K in KS:
        hit, final, _, _ = run_to_epsilon(
            K=K, n=8, sigma=2.0, heterogeneity=1.0, eps=0.6,
            eta_cx=0.02 / K, eta_cy=0.2 / K, max_rounds=400, eval_every=20)
        rows[K] = dict(rounds_to_eps=hit, final_grad=final)
        csv(f"local_steps,K={K},rounds_to_eps={hit},final_grad={final:.4f}")
    return rows
