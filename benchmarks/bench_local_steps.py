"""V2: local updates amortize gradient noise — Theorem 1's σ²/(nK ε⁴) term.

With the theory-prescribed stepsizes (η_c ∝ 1/K for stability), the
per-round update averages K stochastic gradients, so at a fixed round budget
in the noise-dominated regime the stationarity floor improves with K
(equivalently: rounds-to-ε for noise-limited ε falls with K — communication
efficiency).  We report the final ‖∇Φ(x̄)‖ after a fixed 400 rounds under
strong noise (σ=2), plus rounds-to-ε at a noise-limited target.

Thin wrapper over the ``local_steps`` sweep definition: the whole grid runs
as vmapped scan cells (one compiled program per static K cell, seeds
batched) and persists ``results/sweeps/local_steps.json``; CSV lines quote
the seed-0 trajectory, rows add mean±std over the seed replicates.
"""
from __future__ import annotations

from repro.sweep import defs, run as sweep_run

from benchmarks.common import replicate_row

KS = [1, 2, 4, 8, 16]


def run(csv=print):
    res = sweep_run.run_sweep(defs.SWEEPS["local_steps"])
    rows = {}
    for K in KS:
        row = replicate_row(res, K=K)
        rows[K] = row
        csv(f"local_steps,K={K},rounds_to_eps={row['rounds_to_eps']},"
            f"final_grad={row['final_grad']:.4f}"
            f",final_grad_mean={row['final_grad_mean']:.4f}"
            f",final_grad_std={row['final_grad_std']:.4f}")
    return rows
