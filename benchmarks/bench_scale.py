"""Clients-axis scaling benchmark: edge-proportional sparse gossip vs n².

The dense round epilogue contracts an (n, n) mixing matrix against the
packed (n, D) state — O(n²·D) per round, which is what capped the clients
axis at toy sizes.  The sparse neighbor-gather epilogue
(``kernels.ops.sparse_gossip_round`` over ``core.sparse_topology``) costs
O(edges·D).  This benchmark times one full round epilogue at
n ∈ {64, 256, 1024, 4096} on the exponential graph (degree ≈ 2·log₂ n, the
paper's best-gap sparse topology) and fits the log-log cost-vs-n slope:
edge count for the exp graph grows as n·log n, so the sparse slope must
stay well under 2 while dense tracks its n² model.  Dense is measured only
up to ``stochastic_topology.DENSE_MATERIALIZATION_LIMIT``·2 — past that the
matrix materialization is exactly the bug the sparse path removes.

CSV rows: ``scale,impl=...,n=...,edges=...,wall_ms=...`` plus the fitted
slopes.  ``--smoke`` instead compiles and runs ONE sparse round step at
n=256 sharded over the available fake CPU devices (scripts/smoke.sh sets
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` first) and checks
the Σc = 0 tracking invariant — the CI-sized proof that the sparse path
works end to end on a mesh.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparse_topology as sparse_lib
from repro.core import stochastic_topology as stoch_lib
from repro.core import topology as topo_lib
from repro.kernels import ops as kernel_ops

SIZES = (64, 256, 1024, 4096)
D = 256                 # packed state width per client
ETA_S, CORR = 0.5, 12.5


def _synthetic(n: int, seed: int = 0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(k1, (n, D)) * 0.01,
            jax.random.normal(k2, (n, D)),
            jax.random.normal(k3, (n, D)) * 0.1)


def _time_ms(fn, args, reps: int) -> float:
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def _slope(ns, ms) -> float:
    """log-log slope of cost vs n — 2.0 is the dense n² model, the sparse
    exp-graph model is n·log n (slope ≈ 1 + log log corrections)."""
    return float(np.polyfit(np.log(np.asarray(ns, float)),
                            np.log(np.asarray(ms, float)), 1)[0])


def run(csv=print) -> dict:
    results: dict = {"D": D, "topology": "exp", "sparse": {}, "dense": {}}
    sparse_pts, dense_pts = [], []
    for n in SIZES:
        sp = sparse_lib.sparse_exp(n)
        delta, theta, c = _synthetic(n)
        fn = jax.jit(lambda d, t, cc, s=sp: kernel_ops.sparse_gossip_round(
            s.neighbor_idx, s.neighbor_w, s.self_w, d, t, cc, ETA_S, CORR,
            backend="xla"))
        ms = _time_ms(fn, (delta, theta, c), reps=10)
        edges = sp.num_edges
        csv(f"scale,impl=sparse_packed,n={n},edges={edges},"
            f"max_deg={sp.max_degree},wall_ms={ms:.3f},D={D}")
        results["sparse"][str(n)] = {
            "edges": edges, "max_deg": sp.max_degree, "wall_ms": round(ms, 4)}
        sparse_pts.append((n, ms))

        if n <= 2 * stoch_lib.DENSE_MATERIALIZATION_LIMIT:
            w = jnp.asarray(topo_lib.mixing_matrix("exp", n), jnp.float32)
            fd = jax.jit(lambda d, t, cc, ww=w: kernel_ops.fused_gossip_round(
                ww, d, t, cc, ETA_S, CORR, backend="xla"))
            msd = _time_ms(fd, (delta, theta, c), reps=10)
            csv(f"scale,impl=pallas_packed,n={n},edges={n * n},"
                f"wall_ms={msd:.3f},D={D}")
            results["dense"][str(n)] = {"wall_ms": round(msd, 4)}
            dense_pts.append((n, msd))

    results["sparse_loglog_slope"] = round(
        _slope([p[0] for p in sparse_pts], [p[1] for p in sparse_pts]), 3)
    if len(dense_pts) >= 2:
        results["dense_loglog_slope"] = round(
            _slope([p[0] for p in dense_pts], [p[1] for p in dense_pts]), 3)
    # normalized: sparse μs per edge per round should be ~flat across n —
    # the "cost scales with edge count, not n²" claim in one number
    per_edge = {n: ms * 1e3 / results["sparse"][str(n)]["edges"]
                for n, ms in sparse_pts}
    results["sparse_us_per_edge"] = {
        str(n): round(v, 4) for n, v in per_edge.items()}
    csv(f"scale,sparse_loglog_slope={results['sparse_loglog_slope']},"
        f"dense_loglog_slope={results.get('dense_loglog_slope')}")
    results["subquadratic"] = results["sparse_loglog_slope"] < 1.7
    return results


def smoke(n: int = 256) -> int:
    """Compile + run one sparse_packed round step at ``n`` with the clients
    dim sharded over the available (fake) devices; exit 0 iff it runs and
    the Σ_i c_i = 0 invariant holds."""
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.configs.base import AlgorithmConfig
    from repro.core import kgt_minimax as kgt
    from repro.core import objectives

    t0 = time.time()
    ndev = len(jax.devices())
    mesh = Mesh(np.asarray(jax.devices()), ("clients",))
    k_steps = 2
    data = objectives.make_quadratic_data(jax.random.PRNGKey(0), n, dx=8, dy=4)
    problem = objectives.quadratic_problem(data)
    algo = AlgorithmConfig(num_clients=n, local_steps=k_steps, topology="exp",
                           mixing_impl="sparse_packed", eta_cx=0.05,
                           eta_cy=0.05)
    key = jax.random.PRNGKey(1)
    batch1 = {k: data[k] for k in ("A", "B", "b", "q")}
    batches = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (k_steps, *v.shape)), batch1)
    state = kgt.init_state(problem, algo, key, init_batch=batch1,
                           init_keys=jax.random.split(key, n))
    shard = NamedSharding(mesh, P("clients"))
    state = jax.device_put(
        state, kgt.KGTState(x=shard, y=shard, cx=shard, cy=shard,
                            round=NamedSharding(mesh, P())))
    step = jax.jit(kgt.make_round_step(problem, algo))
    keys = jax.random.split(key, k_steps * n).reshape(k_steps, n, 2)
    state = step(state, batches, keys)
    jax.block_until_ready(state.x)
    cmean = float(kgt.correction_mean_norm(state.cx))
    ok = cmean < 1e-3
    print(f"[scale-smoke] sparse_packed round at n={n} on {ndev} devices: "
          f"correction_mean_norm={cmean:.2e} "
          f"({'ok' if ok else 'FAILED'}, {time.time() - t0:.1f}s)",
          flush=True)
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="compile + one sharded sparse round at n=256")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke())
    run()


if __name__ == "__main__":
    main()
