"""V5: linear speedup in n on the stochastic term — at fixed target accuracy
in the noise-dominated regime, rounds-to-ε improves with client count.

Runs through the ``repro.engine`` chunked scan — 4000-round budgets × 4
client counts are exactly the dispatch-bound regime the engine amortizes
(see ``benchmarks.common.run_to_epsilon`` for the evaluation grid)."""
from __future__ import annotations

from benchmarks.common import run_to_epsilon

NS = [2, 4, 8, 16]


def run(csv=print):
    rows = {}
    for n in NS:
        hit, final, _, _ = run_to_epsilon(
            n=n, K=4, sigma=1.0, heterogeneity=0.5, topology="full", eps=0.45,
            eta_cx=0.01, eta_cy=0.1, eta_s=1.0, max_rounds=4000, eval_every=20)
        rows[n] = dict(rounds_to_eps=hit, final_grad=final)
        csv(f"speedup,n={n},rounds={hit},final={final:.4f}")
    return rows
