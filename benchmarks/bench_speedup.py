"""V5: linear speedup in n on the stochastic term — at fixed target accuracy
in the noise-dominated regime, rounds-to-ε improves with client count.

Thin wrapper over the ``speedup`` sweep definition (one vmapped cell per
client count — n changes array shapes, so it is a static axis — seeds
batched), persisted to ``results/sweeps/speedup.json``.
"""
from __future__ import annotations

from repro.sweep import defs, run as sweep_run

from benchmarks.common import replicate_row

NS = [2, 4, 8, 16]


def run(csv=print):
    res = sweep_run.run_sweep(defs.SWEEPS["speedup"])
    rows = {}
    for n in NS:
        row = replicate_row(res, n=n)
        rows[n] = row
        csv(f"speedup,n={n},rounds={row['rounds_to_eps']},"
            f"final={row['final_grad']:.4f}"
            f",rounds_mean={row['rounds_to_eps_mean']}")
    return rows
