"""Sweep throughput: sequential per-point loop vs one vmapped batched cell.

The workload is a 16-seed replicate cell of the paper's toy quadratic
(n=8, K=4, σ=1) run for a fixed 256 rounds (eps=0 so neither path
early-stops).  The sequential path is what the benchmarks did before the
sweep subsystem: drive ``run_to_epsilon`` once per point — one fresh
compile *and* one chunk dispatch per ``eval_every`` interval per point.
The batched path runs the identical 16 trajectories as one
``repro.sweep.batched`` cell: one compile, one chunk dispatch per interval
for the whole batch (the trajectories are bit-identical — that is a test,
not a benchmark claim; see tests/test_sweep.py).

Headline metric: end-to-end trajectories/s — the throughput a sweep user
experiences, where the sequential loop pays one XLA compilation *per point*
(the exact cost ISSUE-4 calls out) and the batched cell compiles once.
Steady-state ``run_s`` throughput (compile and setup split out on both
sides, per the timing satellite) is reported alongside: on this CPU the
vmapped scan's run-only win is bounded by how sublinearly XLA scales the
tiny quadratic ops with batch width, so most of the batched win at this
problem size is amortized compilation; on accelerators the width is free.

The shared per-point *setup* program (``prepare_trajectory``) is warmed
before either path is timed — it is cached process-wide and would otherwise
bill its one-time compile to whichever path ran first.

A third comparison proves the persistent compile cache
(``repro.sweep.cache``): the same batched cell runs in two fresh
subprocesses sharing one cache directory — ``cold_cache`` pays the real
compiles and populates the cache, ``warm_cache`` deserializes executables
from disk.  The rows record the warm run's compile fraction (the ISSUE-10
acceptance bar: < 10% of wall) and that its per-point results are
bit-identical to the cold run's.

CSV rows: ``sweep,mode=...,traj_per_s=...,traj_rounds_per_s=...``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

from repro.sweep import grid, run as sweep_run

B = 16
ROUNDS = 256
EVAL_EVERY = 16

SPEC = grid.GridSpec(
    name="bench_sweep",
    base=dict(n=8, K=4, sigma=1.0, heterogeneity=0.5, topology="ring",
              eta_cx=0.01, eta_cy=0.1, eta_s=0.5, eps=0.0,
              max_rounds=ROUNDS, eval_every=EVAL_EVERY),
    axes=(grid.batch_axis("seed", *range(B)),),
)


def _cache_child(cache_dir: str) -> dict:
    """One fresh-process run of the batched cell against ``cache_dir`` —
    the cold/warm halves of the cache benchmark (invoked via
    ``python -m benchmarks.bench_sweep --cache-child DIR``)."""
    from repro.sweep import cache as cache_lib

    cache_lib.enable_xla_cache(os.path.join(cache_dir, "xla"))
    cache = cache_lib.CompileCache(os.path.join(cache_dir, "aot"))
    [cell] = SPEC.cells()
    results, timing = sweep_run.run_cell(cell, cache=cache)
    return {
        "timing": timing,
        "stats": dict(cache.stats),
        # full float precision round-trips through JSON repr — the parent
        # compares these for bit-identity
        "results": [{"final_grad": r["final_grad"], "history": r["history"]}
                    for r in results],
    }


def _cache_pair(csv) -> dict:
    """Run the cell in two fresh subprocesses sharing one cache directory
    and report cold-vs-warm timing + bit-identity."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    env.pop("REPRO_COMPILE_CACHE", None)  # the child gets an explicit dir
    out: dict = {}
    with tempfile.TemporaryDirectory(prefix="bench_sweep_cache_") as cdir:
        for mode in ("cold_cache", "warm_cache"):
            proc = subprocess.run(
                [sys.executable, "-m", "benchmarks.bench_sweep",
                 "--cache-child", cdir],
                capture_output=True, text=True, cwd=root, env=env)
            if proc.returncode != 0:
                raise RuntimeError(
                    f"cache child ({mode}) failed:\n{proc.stderr}")
            rec = json.loads(proc.stdout)
            t = rec["timing"]
            frac = (t["compile_s"] / t["wall_s"]) if t["wall_s"] else 0.0
            rec["compile_frac"] = round(frac, 3)
            csv(f"sweep,mode={mode},B={B},rounds={ROUNDS},"
                f"wall_s={t['wall_s']},compile_s={t['compile_s']},"
                f"run_s={t['run_s']},compile_frac={rec['compile_frac']},"
                f"cache_hits={int(rec['stats']['hits'])},"
                f"cache_misses={int(rec['stats']['misses'])}")
            out[mode] = rec
    identical = out["cold_cache"]["results"] == out["warm_cache"]["results"]
    out["bit_identical"] = identical
    out["warm_compile_frac"] = out["warm_cache"]["compile_frac"]
    csv(f"sweep,summary_cache,warm_compile_frac={out['warm_compile_frac']},"
        f"bit_identical={identical}")
    for mode in ("cold_cache", "warm_cache"):
        del out[mode]["results"]  # bulky; identity is already asserted
    return out


def run(csv=print) -> dict:
    [cell] = SPEC.cells()
    sweep_run.prepare_trajectory(cell.points[0])  # warm the shared preparer

    # batched: the whole cell as one vmapped program (cache off: these two
    # rows isolate batching, not persistence — the cache rows follow)
    t0 = time.perf_counter()
    results, bt = sweep_run.run_cell(cell, cache=None)
    batched_wall = time.perf_counter() - t0
    assert all(r["history"][-1][0] == ROUNDS for r in results)
    batched_tps = B / batched_wall
    batched_rps = B * ROUNDS / bt["run_s"]
    csv(f"sweep,mode=batched,B={B},rounds={ROUNDS},"
        f"traj_per_s={batched_tps:.2f},traj_rounds_per_s={batched_rps:.0f},"
        f"compile_s={bt['compile_s']},run_s={bt['run_s']}")

    # sequential: one run_point per trajectory — the pre-sweep benchmark
    # execution model, which recompiles its programs for every point
    # (run_point builds fresh jit closures each call, exactly as the
    # historical run_to_epsilon did)
    t0 = time.perf_counter()
    seq_run_s = seq_compile_s = seq_setup_s = 0.0
    for p in cell.points:
        hit, final, timing, hist = sweep_run.run_point(p, cache=None)
        seq_run_s += timing["run_s"]
        seq_compile_s += timing["compile_s"]
        seq_setup_s += timing["setup_s"]
    seq_wall = time.perf_counter() - t0
    seq_tps = B / seq_wall
    seq_rps = B * ROUNDS / seq_run_s
    csv(f"sweep,mode=sequential,B={B},rounds={ROUNDS},"
        f"traj_per_s={seq_tps:.2f},traj_rounds_per_s={seq_rps:.0f},"
        f"compile_s={seq_compile_s:.2f},run_s={seq_run_s:.2f}")

    speedup = seq_wall / batched_wall
    speedup_run = batched_rps / seq_rps
    csv(f"sweep,summary,speedup_traj_per_s={speedup:.2f}x,"
        f"speedup_run_only={speedup_run:.2f}x")
    cache_pair = _cache_pair(csv)
    return {
        "B": B, "rounds": ROUNDS, "eval_every": EVAL_EVERY,
        "cache": cache_pair,
        "batched": {"traj_per_s": round(batched_tps, 2),
                    "traj_rounds_per_s": round(batched_rps, 1),
                    "wall_s": round(batched_wall, 3), **bt},
        "sequential": {
            "traj_per_s": round(seq_tps, 2),
            "traj_rounds_per_s": round(seq_rps, 1),
            "wall_s": round(seq_wall, 3),
            "compile_s": round(seq_compile_s, 3),
            "setup_s": round(seq_setup_s, 3),
            "run_s": round(seq_run_s, 3),
        },
        "speedup_traj_per_s": round(speedup, 2),
        "speedup_run_only": round(speedup_run, 2),
    }


if __name__ == "__main__":
    if len(sys.argv) == 3 and sys.argv[1] == "--cache-child":
        print(json.dumps(_cache_child(sys.argv[2])))
    else:
        run()
