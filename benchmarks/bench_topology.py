"""V4: topology dependence — rounds-to-ε vs spectral quantity p (Theorem 1's
kappa^3/(p^2 eps^2) term): full > exp > ring in connectivity.

Thin wrapper over the ``topology`` sweep definition (one vmapped cell per
topology, seeds batched), persisted to ``results/sweeps/topology.json``.
"""
from __future__ import annotations

from repro.core import mixing_matrix, spectral_gap
from repro.sweep import defs, run as sweep_run

from benchmarks.common import replicate_row

TOPOLOGIES = ["full", "exp", "torus", "ring"]


def run(csv=print):
    spec = defs.SWEEPS["topology"]
    n = spec.base["n"]
    res = sweep_run.run_sweep(spec)
    rows = {}
    for topo in TOPOLOGIES:
        p = spectral_gap(mixing_matrix(topo, n))
        row = replicate_row(res, topology=topo)
        rows[topo] = dict(p=round(p, 4), **row)
        csv(f"topology,{topo},p={p:.3f},rounds={row['rounds_to_eps']},"
            f"final={row['final_grad']:.4f}"
            f",rounds_mean={row['rounds_to_eps_mean']}")
    return rows
