"""V4: topology dependence — rounds-to-ε vs spectral quantity p (Theorem 1's
kappa^3/(p^2 eps^2) term): full > exp > ring in connectivity."""
from __future__ import annotations

from repro.core import mixing_matrix, spectral_gap

from benchmarks.common import run_to_epsilon

TOPOLOGIES = ["full", "exp", "torus", "ring"]


def run(csv=print, n: int = 16):
    rows = {}
    for topo in TOPOLOGIES:
        p = spectral_gap(mixing_matrix(topo, n))
        hit, final, _, _ = run_to_epsilon(
            topology=topo, n=n, K=4, sigma=0.0, heterogeneity=2.0, eps=0.2,
            eta_cx=0.01, eta_cy=0.1, eta_s=min(0.9, 0.6 + 0.4 * p),
            max_rounds=2500)
        rows[topo] = dict(p=round(p, 4), rounds_to_eps=hit, final_grad=final)
        csv(f"topology,{topo},p={p:.3f},rounds={hit},final={final:.4f}")
    return rows
