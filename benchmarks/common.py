"""Shared harness for the theory-validation benchmarks (V1–V6 in DESIGN.md).

All benchmarks run the synthetic NC-SC quadratic (exact ∇Φ oracle) because
the paper's claims are about convergence/communication complexity, not about
any particular model.  Each benchmark emits CSV rows and returns a dict for
EXPERIMENTS.md.

Execution goes through ``repro.engine``: rounds run as compiled
``eval_every``-long scan chunks (one dispatch per evaluation interval
instead of one per round), with the exact ∇Φ oracle evaluated on the
chunk-boundary state — the same grid the historical per-round loop used
(after eval_every, 2·eval_every, … rounds) with an immediate stop at the
first grid point under eps.  One deliberate delta: when ``eval_every``
does not divide ``max_rounds``, the run's final state is also evaluated
(the old loop left a tail of rounds unmeasured).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import engine as engine_lib
from repro.configs.base import AlgorithmConfig
from repro.core import (
    init_state,
    make_quadratic_data,
    make_round_step,
    mean_over_clients,
    quadratic_problem,
)

DX, DY = 10, 5


def run_to_epsilon(
    *,
    n: int = 8,
    K: int = 4,
    sigma: float = 0.1,
    heterogeneity: float = 1.0,
    topology: str = "ring",
    algorithm: str = "kgt_minimax",
    eta_cx: float = 0.01,
    eta_cy: float = 0.1,
    eta_s: float = 0.5,
    eps: float = 0.3,
    max_rounds: int = 2000,
    seed: int = 0,
    mixing_impl: str = "dense",
    eval_every: int = 10,
):
    """Returns (rounds_to_eps or None, final ||grad Phi||, wall_s, history)."""
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=DX, dy=DY, heterogeneity=heterogeneity)
    prob = quadratic_problem(data, sigma=sigma)
    cfg = AlgorithmConfig(algorithm=algorithm, num_clients=n, local_steps=K,
                          eta_cx=eta_cx, eta_cy=eta_cy, eta_sx=eta_s, eta_sy=eta_s,
                          topology=topology, mixing_impl=mixing_impl)
    cb = {k: v for k, v in data.items() if k != "mu"}
    kb = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (cfg.local_steps, *v.shape)), cb)
    st = init_state(prob, cfg, key, init_batch=cb,
                    init_keys=jax.random.split(key, n))

    sampler = engine_lib.make_fixed_batch_sampler(
        kb, local_steps=cfg.local_steps, num_clients=n, seed=seed)
    build = engine_lib.make_chunk_builder(
        make_round_step(prob, cfg), sampler)
    grad_fn = jax.jit(lambda s: prob.phi_grad_norm(mean_over_clients(s.x)))

    hist = []
    hit = None
    final_round = jnp.int32(max_rounds - 1)
    t0 = time.time()
    r = 0
    while r < max_rounds:
        length = min(eval_every, max_rounds - r)
        st, _ = build(length)(st, final_round)
        r += length
        g = float(grad_fn(st))
        hist.append((r, g))
        if g < eps:
            hit = r
            break
    final = hist[-1][1] if hist else float("nan")
    return hit, final, time.time() - t0, hist
