"""Shared harness for the theory-validation benchmarks (V1–V6 in DESIGN.md).

All benchmarks run the synthetic NC-SC quadratic (exact ∇Φ oracle) because
the paper's claims are about convergence/communication complexity, not about
any particular model.  Each benchmark emits CSV rows and returns a dict for
EXPERIMENTS.md.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AlgorithmConfig
from repro.core import (
    diagnostics,
    init_state,
    make_quadratic_data,
    make_round_step,
    quadratic_problem,
)

DX, DY = 10, 5


def run_to_epsilon(
    *,
    n: int = 8,
    K: int = 4,
    sigma: float = 0.1,
    heterogeneity: float = 1.0,
    topology: str = "ring",
    algorithm: str = "kgt_minimax",
    eta_cx: float = 0.01,
    eta_cy: float = 0.1,
    eta_s: float = 0.5,
    eps: float = 0.3,
    max_rounds: int = 2000,
    seed: int = 0,
    mixing_impl: str = "dense",
    eval_every: int = 10,
):
    """Returns (rounds_to_eps or None, final ||grad Phi||, wall_s, history)."""
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=DX, dy=DY, heterogeneity=heterogeneity)
    prob = quadratic_problem(data, sigma=sigma)
    cfg = AlgorithmConfig(algorithm=algorithm, num_clients=n, local_steps=K,
                          eta_cx=eta_cx, eta_cy=eta_cy, eta_sx=eta_s, eta_sy=eta_s,
                          topology=topology, mixing_impl=mixing_impl)
    cb = {k: v for k, v in data.items() if k != "mu"}
    kb = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (cfg.local_steps, *v.shape)), cb)
    k_eff = cfg.local_steps
    st = init_state(prob, cfg, key, init_batch=cb,
                    init_keys=jax.random.split(key, n))
    step = jax.jit(make_round_step(prob, cfg))
    grad_fn = jax.jit(lambda s: prob.phi_grad_norm(
        jax.tree.map(lambda x: x.mean(0), s.x)))

    hist = []
    hit = None
    t0 = time.time()
    for t in range(max_rounds):
        keys = jax.random.split(jax.random.PRNGKey(seed * 7919 + t),
                                k_eff * n).reshape(k_eff, n, 2)
        st = step(st, kb, keys)
        if (t + 1) % eval_every == 0:
            g = float(grad_fn(st))
            hist.append((t + 1, g))
            if hit is None and g < eps:
                hit = t + 1
                break
    final = hist[-1][1] if hist else float("nan")
    return hit, final, time.time() - t0, hist
