"""Shared harness for the theory-validation benchmarks (V1–V6 in DESIGN.md).

All benchmarks run the synthetic NC-SC quadratic (exact ∇Φ oracle) because
the paper's claims are about convergence/communication complexity, not about
any particular model.  Each benchmark emits CSV rows and returns a dict for
EXPERIMENTS.md.

``run_to_epsilon`` is the one-configuration entrypoint; since the sweep
subsystem landed it delegates to ``repro.sweep.run.run_point``, which jits
the *same* trajectory program the batched sweep cells vmap (per-trajectory
stepsizes/σ/seed as traced operands, rounds as compiled ``eval_every``-long
scan chunks, ∇Φ checked on the chunk-boundary state with an immediate stop
at the first grid point under eps).  That sharing is what makes a batched
sweep bit-identical to the sequential runs it replaces — see
``repro.sweep.batched`` and tests/test_sweep.py.

The grid-shaped benchmarks (``bench_{local_steps,heterogeneity,topology,
speedup,convergence}``) are thin wrappers over the sweep definitions in
``repro.sweep.defs`` and no longer loop over ``run_to_epsilon`` point by
point; it remains the reference path (``bench_sweep`` measures the gap) and
the one-off-experiment API.
"""
from __future__ import annotations

from repro.sweep import run as sweep_run

DX, DY = sweep_run.DX, sweep_run.DY


def run_to_epsilon(
    *,
    n: int = 8,
    K: int = 4,
    sigma: float = 0.1,
    heterogeneity: float = 1.0,
    topology: str = "ring",
    algorithm: str = "kgt_minimax",
    eta_cx: float = 0.01,
    eta_cy: float = 0.1,
    eta_s: float = 0.5,
    eps: float = 0.3,
    max_rounds: int = 2000,
    seed: int = 0,
    mixing_impl: str = "dense",
    eval_every: int = 10,
):
    """Returns ``(rounds_to_eps or None, final ‖∇Φ‖, timing, history)``.

    ``timing`` splits the wall clock into ``compile_s`` (XLA compilation,
    AOT-timed), ``setup_s`` (data/init), and steady-state ``run_s`` — the
    historical single ``wall_s`` folded first-chunk compilation into every
    rounds/s and time-to-ε number.  ``timing["wall_s"]`` is still the total.
    """
    return sweep_run.run_point(dict(
        n=n, K=K, sigma=sigma, heterogeneity=heterogeneity,
        topology=topology, algorithm=algorithm, eta_cx=eta_cx,
        eta_cy=eta_cy, eta_s=eta_s, eps=eps, max_rounds=max_rounds,
        seed=seed, mixing_impl=mixing_impl, eval_every=eval_every))


def seed0_point(result: dict, **params) -> dict:
    """The seed-0 record of a replicate group in a sweep result — the
    benchmarks' CSV lines quote it so their rows stay comparable with the
    historical one-run-per-point output."""
    pts = sweep_run.points_where(result, seed=0, **params)
    if not pts:
        raise KeyError(f"no seed-0 point matching {params}")
    return pts[0]


def replicate_row(result: dict, **params) -> dict:
    """Benchmark row for one figure point: seed-0 values (historical keys)
    + mean±std over the seed replicates."""
    p0 = seed0_point(result, **params)
    agg = sweep_run.summarize(sweep_run.points_where(result, **params))
    return {
        "rounds_to_eps": p0["rounds_to_eps"],
        "final_grad": p0["final_grad"],
        **agg,
    }
