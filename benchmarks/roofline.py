"""Roofline report: derive the three per-device time terms for every
(arch x shape x mesh) entry of the dry-run JSONL.

  compute_s    = parsed dot FLOPs / 197e12           (bf16 MXU peak, v5e)
  memory_s     = parsed HBM traffic / 819e9          (HBM bandwidth)
  collective_s = parsed collective bytes / 50e9      (per-link ICI proxy)

FLOPs/traffic/collective bytes come from the loop-aware HLO parse
(repro.analysis.hlo_cost) — XLA's own cost_analysis counts while bodies once.
MODEL_FLOPS uses 6·N·D (train, N=active params) / 2·N·D (inference) per
device; the ratio against parsed FLOPs measures remat/dispatch overhead.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.configs import registry
from repro.configs.shapes import SHAPES
from repro.launch import mesh as mesh_lib

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link (proxy: all parsed bytes over 1 link)


def model_flops_per_device(arch: str, shape_name: str, mesh_kind: str) -> float:
    cfg = registry.get_model_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    multi = mesh_kind == "multi"
    chips = 512 if multi else 256
    if shape.kind == "train":
        mcfg = mesh_lib.decentralized_mesh_config(arch, multi_pod=multi)
        k_steps = 2  # dry-run AlgorithmConfig default
        tokens_per_client = shape.global_batch // mcfg.num_clients * shape.seq_len
        per_client_chips = mcfg.fsdp * mcfg.model
        return k_steps * 6.0 * n_active * tokens_per_client / per_client_chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch / chips


def load(path: str) -> List[Dict]:
    return [json.loads(l) for l in open(path) if l.strip()]


def analyze_entry(r: Dict) -> Optional[Dict]:
    if "error" in r:
        return None
    coll = sum(v for k, v in r["collectives"].items() if not k.startswith("n_"))
    compute_s = r["cost"]["dot_flops"] / PEAK_FLOPS
    memory_s = r["cost"]["traffic_bytes"] / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(r["arch"], r["shape"], r["mesh"])
    useful = mf / r["cost"]["dot_flops"] if r["cost"]["dot_flops"] else 0.0
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": mf, "useful_ratio": useful,
        "peak_gib": r["memory"]["peak_per_device"] / 2**30,
    }


def what_would_help(row: Dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("cut gossip/FSDP bytes: bf16 gossip, ring ppermute, fewer "
                "param regathers per local step")
    if d == "memory":
        return "raise arithmetic intensity: fuse, larger per-chip tiles, remat less"
    if row["useful_ratio"] < 0.4:
        return "compute-bound but wasteful: reduce remat/dispatch FLOPs"
    return "compute-bound near roofline: scale batch or accept"


def table(path: str, meshes=("single",)) -> str:
    rows = [analyze_entry(r) for r in load(path)]
    rows = [r for r in rows if r and r["mesh"] in meshes]
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | 6ND/HLO | peak GiB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['peak_gib']:.1f} |")
    return "\n".join(out)


def run(csv=print, path: str = "/root/repo/results/dryrun.jsonl"):
    rows = [analyze_entry(r) for r in load(path)]
    rows = [r for r in rows if r]
    for r in rows:
        csv(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
            f"compute_s={r['compute_s']:.4f},memory_s={r['memory_s']:.4f},"
            f"collective_s={r['collective_s']:.4f},dominant={r['dominant']},"
            f"useful={r['useful_ratio']:.3f}")
    return rows


if __name__ == "__main__":
    import sys

    path = sys.argv[1] if len(sys.argv) > 1 else "/root/repo/results/dryrun.jsonl"
    print(table(path, meshes=("single", "multi")))
