"""Benchmark runner — one benchmark per paper claim (Table 1 and Theorem 1's
scaling terms) plus the roofline report over the dry-run artifacts.

Prints ``name,key=value,...`` CSV lines and writes results/benchmarks.json
(repo-root-relative, stamped with provenance and merged — partial runs like
``run gossip`` in CI don't clobber earlier benchmarks).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run convergence topology
"""
from __future__ import annotations

import json
import os
import sys
import time

from benchmarks import (
    bench_churn,
    bench_convergence,
    bench_engine,
    bench_gossip,
    bench_heterogeneity,
    bench_local_steps,
    bench_scale,
    bench_speedup,
    bench_sweep,
    bench_topology,
    roofline,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_PATH = os.path.join(REPO_ROOT, "results", "benchmarks.json")

BENCHES = {
    "convergence": bench_convergence.run,      # Table 1 proxy: vs baselines
    "local_steps": bench_local_steps.run,      # V2: T vs K
    "heterogeneity": bench_heterogeneity.run,  # V3: DH robustness
    "topology": bench_topology.run,            # V4: T vs p
    "speedup": bench_speedup.run,              # V5: linear speedup in n
    "churn": bench_churn.run,                  # V6: random topologies + participation
    "gossip": bench_gossip.run,                # round-epilogue lowerings
    "scale": bench_scale.run,                  # sparse gossip: cost vs n (edges, not n²)
    "engine": bench_engine.run,                # host loop vs scanned chunks
    "sweep": bench_sweep.run,                  # sequential loop vs vmapped cell
    "roofline": roofline.run,                  # deliverable (g)
}


def _provenance() -> dict:
    from repro.sweep import store as sweep_store

    return sweep_store.provenance()


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    results = {}
    for name in names:
        fn = BENCHES[name]
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            results[name] = fn(csv=lambda s: print(s, flush=True))
        except FileNotFoundError as e:
            print(f"{name},SKIPPED,missing artifact: {e}", flush=True)
            continue
        print(f"{name},wall_s={time.time()-t0:.1f}", flush=True)
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    # merge into existing results so partial runs (e.g. `run gossip` in CI)
    # don't clobber earlier benchmarks
    merged = {}
    if os.path.exists(RESULTS_PATH):
        try:
            with open(RESULTS_PATH) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged.update(results)
    merged["_provenance"] = _provenance()
    with open(RESULTS_PATH, "w") as f:
        json.dump(merged, f, indent=1, default=str)


if __name__ == "__main__":
    main()
