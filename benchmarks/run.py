"""Benchmark runner — one benchmark per paper claim (Table 1 and Theorem 1's
scaling terms) plus the roofline report over the dry-run artifacts.

Prints ``name,key=value,...`` CSV lines and writes results/benchmarks.json
(repo-root-relative, stamped with provenance and merged — partial runs like
``run gossip`` in CI don't clobber earlier benchmarks).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run convergence topology
  PYTHONPATH=src python -m benchmarks.run --benches gossip,engine
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks import (
    bench_adversary,
    bench_churn,
    bench_convergence,
    bench_engine,
    bench_gossip,
    bench_heterogeneity,
    bench_local_steps,
    bench_scale,
    bench_speedup,
    bench_sweep,
    bench_topology,
    roofline,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_PATH = os.path.join(REPO_ROOT, "results", "benchmarks.json")

BENCHES = {
    "convergence": bench_convergence.run,      # Table 1 proxy: vs baselines
    "local_steps": bench_local_steps.run,      # V2: T vs K
    "heterogeneity": bench_heterogeneity.run,  # V3: DH robustness
    "topology": bench_topology.run,            # V4: T vs p
    "speedup": bench_speedup.run,              # V5: linear speedup in n
    "churn": bench_churn.run,                  # V6: random topologies + participation
    "adversary": bench_adversary.run,          # V7: Byzantine clients vs robust gossip
    "gossip": bench_gossip.run,                # round-epilogue lowerings
    "scale": bench_scale.run,                  # sparse gossip: cost vs n (edges, not n²)
    "engine": bench_engine.run,                # host loop vs scanned chunks
    "sweep": bench_sweep.run,                  # sequential loop vs vmapped cell
    "roofline": roofline.run,                  # deliverable (g)
}


def _provenance(**extra) -> dict:
    from repro.sweep import store as sweep_store

    return sweep_store.provenance(**extra)


def _parse_names(argv) -> list:
    """Positional names and/or ``--benches a,b,c`` (union, order-preserving,
    unknown names rejected up front instead of KeyError-ing mid-run)."""
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("names", nargs="*", help="benchmarks to run (default all)")
    ap.add_argument("--benches", default=None, metavar="A,B,...",
                    help="comma-separated benchmark filter")
    args = ap.parse_args(argv)
    names = list(args.names)
    if args.benches:
        names += [s for s in args.benches.split(",") if s]
    seen = set()
    names = [n for n in names if not (n in seen or seen.add(n))]
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; choose from "
                 f"{sorted(BENCHES)}")
    return names or list(BENCHES)


def main() -> None:
    names = _parse_names(sys.argv[1:])
    results = {}
    failures = {}
    bench_wall_s = {}
    for name in names:
        fn = BENCHES[name]
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            rows = fn(csv=lambda s: print(s, flush=True))
        except FileNotFoundError as e:
            print(f"{name},SKIPPED,missing artifact: {e}", flush=True)
            continue
        except Exception as e:  # noqa: BLE001 — one bench must not eat the rest
            # a crashing bench used to abort main() before the merged-store
            # write, silently discarding every benchmark that had already
            # finished; record it, keep going, and fail the run at the end
            failures[name] = repr(e)
            print(f"{name},FAILED,{e!r}", flush=True)
            continue
        wall = time.time() - t0
        if not rows:
            failures[name] = "returned no rows"
            print(f"{name},FAILED,returned no rows", flush=True)
            continue
        results[name] = rows
        bench_wall_s[name] = round(wall, 3)
        print(f"{name},wall_s={wall:.1f}", flush=True)
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    # merge into existing results so partial runs (e.g. `run gossip` in CI)
    # don't clobber earlier benchmarks
    merged = {}
    if os.path.exists(RESULTS_PATH):
        try:
            with open(RESULTS_PATH) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged.update(results)
    # per-bench wall seconds merge like the results: a partial rerun updates
    # its own benches' timings and keeps the rest
    prev_prov = merged.get("_provenance") or {}
    walls = dict(prev_prov.get("bench_wall_s") or {})
    walls.update(bench_wall_s)
    merged["_provenance"] = _provenance(bench_wall_s=walls)
    with open(RESULTS_PATH, "w") as f:
        json.dump(merged, f, indent=1, default=str)
    # a bench that produced rows must land in the merged store — re-read and
    # check, so a serialization bug can't silently drop a benchmark entry
    with open(RESULTS_PATH) as f:
        stored = json.load(f)
    for name in results:
        if not stored.get(name):
            failures[name] = "rows produced but missing from merged store"
    if failures:
        for name, why in sorted(failures.items()):
            print(f"benchmarks,FAILED,{name}: {why}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
