"""Benchmark runner — one benchmark per paper claim (Table 1 and Theorem 1's
scaling terms) plus the roofline report over the dry-run artifacts.

Prints ``name,key=value,...`` CSV lines and writes results/benchmarks.json.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run convergence topology
"""
from __future__ import annotations

import json
import os
import sys
import time

from benchmarks import (
    bench_convergence,
    bench_engine,
    bench_gossip,
    bench_heterogeneity,
    bench_local_steps,
    bench_speedup,
    bench_topology,
    roofline,
)

BENCHES = {
    "convergence": bench_convergence.run,      # Table 1 proxy: vs baselines
    "local_steps": bench_local_steps.run,      # V2: T vs K
    "heterogeneity": bench_heterogeneity.run,  # V3: DH robustness
    "topology": bench_topology.run,            # V4: T vs p
    "speedup": bench_speedup.run,              # V5: linear speedup in n
    "gossip": bench_gossip.run,                # round-epilogue lowerings
    "engine": bench_engine.run,                # host loop vs scanned chunks
    "roofline": roofline.run,                  # deliverable (g)
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    results = {}
    for name in names:
        fn = BENCHES[name]
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            results[name] = fn(csv=lambda s: print(s, flush=True))
        except FileNotFoundError as e:
            print(f"{name},SKIPPED,missing artifact: {e}", flush=True)
            continue
        print(f"{name},wall_s={time.time()-t0:.1f}", flush=True)
    os.makedirs("/root/repo/results", exist_ok=True)
    path = "/root/repo/results/benchmarks.json"
    # merge into existing results so partial runs (e.g. `run gossip` in CI)
    # don't clobber earlier benchmarks
    merged = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged.update(results)
    with open(path, "w") as f:
        json.dump(merged, f, indent=1, default=str)


if __name__ == "__main__":
    main()
