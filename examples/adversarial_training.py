"""Adversarial-embedding minimax training (the paper's adversarial-training
application): y is a universal embedding perturbation ascended jointly while
x descends — run decentralized with K-GT-Minimax on the chunked engine
(``repro.engine``): rounds execute as scanned chunks with the heterogeneous
token data sampled on device and clean/adversarial losses streamed through
the metrics buffer (a custom ``metrics_fn`` — the engine is metric-agnostic).

  PYTHONPATH=src python examples/adversarial_training.py --rounds 40
"""
import argparse

import jax
import jax.numpy as jnp

from repro import engine as engine_lib
from repro.configs.base import AlgorithmConfig
from repro.configs.registry import get_model_config, reduced
from repro.core import adversarial_problem, init_state, make_round_step
from repro.data import make_data_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--chunk", type=int, default=10)
    args = ap.parse_args()

    cfg = reduced(get_model_config(args.arch))
    n, K = args.clients, args.local_steps
    problem = adversarial_problem(cfg, mu=10.0, scale=0.1)
    algo = AlgorithmConfig(num_clients=n, local_steps=K, eta_cx=0.02,
                           eta_cy=0.05, eta_sx=0.7, eta_sy=0.7, topology="ring")

    key = jax.random.PRNGKey(0)
    dm = make_data_model(key, vocab_size=cfg.vocab_size, num_groups=4,
                         num_clients=n, alpha=0.3)
    # disjoint key streams: the sampler folds the round index into k_train,
    # so the eval key must NOT come from fold_in(k_train, ·) or the "held
    # out" batch would collide with some round's training data
    k_train, k_eval = jax.random.split(key)
    sampler = engine_lib.make_dro_sampler(
        dm, k_train, local_steps=K, num_clients=n, per_client_batch=2,
        seq_len=64, cfg=cfg)
    batches0, _ = sampler(jnp.int32(0))
    state = init_state(problem, algo, key,
                       init_batch=jax.tree.map(lambda x: x[0], batches0),
                       init_keys=jax.random.split(key, n))

    # held-out eval batch: clean vs adversarial loss of the consensus model
    eval_b = engine_lib.held_out_eval_batch(
        dm, k_eval, num_clients=n, per_client_batch=2, seq_len=64, cfg=cfg)

    def metrics_fn(state, batches):
        xbar = jax.tree.map(lambda x: x.mean(0), state.x)
        ybar = state.y.mean(0)
        return {
            "clean_loss": problem.value(xbar, jnp.zeros_like(ybar), eval_b, None),
            "adv_loss": problem.value(xbar, ybar, eval_b, None),
            "y_norm": jnp.linalg.norm(ybar),
        }

    build = engine_lib.make_chunk_builder(
        make_round_step(problem, algo), sampler, metrics_fn, log_every=10)

    def show(state, records, prev_round):
        for r in records:
            print(f"round {r['round']:3d}  clean loss {r['clean_loss']:.4f}  "
                  f"adversarial loss {r['adv_loss']:.4f}  "
                  f"|y| {r['y_norm']:.4f}", flush=True)

    engine_lib.run(state, build, total_rounds=args.rounds,
                   chunk_rounds=args.chunk, hooks=[show], wall_clock=False)


if __name__ == "__main__":
    main()
