"""Adversarial-embedding minimax training (the paper's adversarial-training
application): y is a universal embedding perturbation ascended jointly while
x descends — run decentralized with K-GT-Minimax.

  PYTHONPATH=src python examples/adversarial_training.py --rounds 40
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import AlgorithmConfig
from repro.configs.registry import get_model_config, reduced
from repro.core import adversarial_problem, init_state, make_round_step
from repro.data import make_data_model, round_batches


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    args = ap.parse_args()

    cfg = reduced(get_model_config(args.arch))
    n, K = args.clients, args.local_steps
    problem = adversarial_problem(cfg, mu=10.0, scale=0.1)
    algo = AlgorithmConfig(num_clients=n, local_steps=K, eta_cx=0.02,
                           eta_cy=0.05, eta_sx=0.7, eta_sy=0.7, topology="ring")

    key = jax.random.PRNGKey(0)
    dm = make_data_model(key, vocab_size=cfg.vocab_size, num_groups=4,
                         num_clients=n, alpha=0.3)
    batches0 = round_batches(dm, key, local_steps=1, num_clients=n,
                             per_client_batch=2, seq_len=64, cfg=cfg)
    state = init_state(problem, algo, key,
                       init_batch=jax.tree.map(lambda x: x[0], batches0),
                       init_keys=jax.random.split(key, n))
    step = jax.jit(make_round_step(problem, algo))

    for t in range(args.rounds):
        kb = jax.random.fold_in(key, t)
        batches = round_batches(dm, kb, local_steps=K, num_clients=n,
                                per_client_batch=2, seq_len=64, cfg=cfg)
        keys = jax.random.split(kb, K * n).reshape(K, n, 2)
        state = step(state, batches, keys)
        if t % 10 == 0 or t == args.rounds - 1:
            eval_b = jax.tree.map(lambda x: x[0, 0], batches)
            xbar = jax.tree.map(lambda x: x.mean(0), state.x)
            ybar = state.y.mean(0)
            clean = problem.value(xbar, jnp.zeros_like(ybar), eval_b, None)
            robust = problem.value(xbar, ybar, eval_b, None)
            print(f"round {t:3d}  clean loss {float(clean):.4f}  "
                  f"adversarial loss {float(robust):.4f}  "
                  f"|y| {float(jnp.linalg.norm(ybar)):.4f}", flush=True)


if __name__ == "__main__":
    main()
