"""Quickstart: K-GT-Minimax on a synthetic heterogeneous NC-SC problem.

Five-minute tour of the public API: build a problem, a topology, the
algorithm state, run rounds through the chunked execution engine
(``repro.engine``: 60-round ``lax.scan`` chunks, exact-oracle diagnostics
streamed through the on-device metrics buffer), watch ||grad Phi|| fall
while plain local SGDA stalls.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import engine as engine_lib
from repro.configs.base import AlgorithmConfig
from repro.core import (
    init_state,
    make_quadratic_data,
    make_round_step,
    quadratic_problem,
)

N_CLIENTS, K = 8, 8
ROUNDS, LOG_EVERY = 300, 60


def run(algorithm: str):
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, N_CLIENTS, dx=10, dy=5, heterogeneity=2.0)
    problem = quadratic_problem(data, sigma=0.1)
    cfg = AlgorithmConfig(
        algorithm=algorithm, num_clients=N_CLIENTS, local_steps=K,
        eta_cx=0.01, eta_cy=0.1,
        eta_sx=0.5 if algorithm == "kgt_minimax" else 1.0,
        eta_sy=0.5 if algorithm == "kgt_minimax" else 1.0,
        topology="ring")

    client_batch = {k: v for k, v in data.items() if k != "mu"}
    batches = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), client_batch)
    state = init_state(problem, cfg, key, init_batch=client_batch,
                       init_keys=jax.random.split(key, N_CLIENTS))

    # the engine pieces: a per-round sampler (fixed batch + per-round oracle
    # keys), the exact-∇Φ metrics row, and the chunked scan program
    sampler = engine_lib.make_fixed_batch_sampler(
        batches, local_steps=K, num_clients=N_CLIENTS, seed=0)
    build = engine_lib.make_chunk_builder(
        make_round_step(problem, cfg), sampler,
        engine_lib.quadratic_metrics_fn(problem), log_every=LOG_EVERY)

    print(f"\n=== {algorithm} (n={N_CLIENTS}, K={K}, ring, "
          f"chunk={LOG_EVERY}) ===")

    def show(state, records, prev_round):
        for r in records:
            print(f"round {r['round']:4d}  ||grad Phi(x̄)|| = "
                  f"{r['phi_grad_norm']:.4f}   consensus Ξx = "
                  f"{r['consensus_x']:.2e}")

    _, history = engine_lib.run(
        state, build, total_rounds=ROUNDS, chunk_rounds=LOG_EVERY,
        hooks=[show], wall_clock=False)
    return history[-1]["phi_grad_norm"]


if __name__ == "__main__":
    g_kgt = run("kgt_minimax")
    g_local = run("local_sgda")
    print(f"\nK-GT-Minimax reaches ||grad|| = {g_kgt:.4f}; "
          f"local SGDA (no tracking) stalls at {g_local:.4f} "
          f"under the same heterogeneity — the paper's DH-robustness claim.")
