"""Quickstart: K-GT-Minimax on a synthetic heterogeneous NC-SC problem.

Five-minute tour of the public API: build a problem, a topology, the
algorithm state, run rounds, watch ||grad Phi|| (exact oracle) fall while
plain local SGDA stalls.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import AlgorithmConfig
from repro.core import (
    diagnostics,
    init_state,
    make_quadratic_data,
    make_round_step,
    quadratic_problem,
)

N_CLIENTS, K = 8, 8


def run(algorithm: str):
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, N_CLIENTS, dx=10, dy=5, heterogeneity=2.0)
    problem = quadratic_problem(data, sigma=0.1)
    cfg = AlgorithmConfig(
        algorithm=algorithm, num_clients=N_CLIENTS, local_steps=K,
        eta_cx=0.01, eta_cy=0.1,
        eta_sx=0.5 if algorithm == "kgt_minimax" else 1.0,
        eta_sy=0.5 if algorithm == "kgt_minimax" else 1.0,
        topology="ring")

    client_batch = {k: v for k, v in data.items() if k != "mu"}
    batches = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), client_batch)
    state = init_state(problem, cfg, key, init_batch=client_batch,
                       init_keys=jax.random.split(key, N_CLIENTS))
    step = jax.jit(make_round_step(problem, cfg))

    print(f"\n=== {algorithm} (n={N_CLIENTS}, K={K}, ring) ===")
    for t in range(301):
        keys = jax.random.split(jax.random.PRNGKey(t), K * N_CLIENTS)
        state = step(state, batches, keys.reshape(K, N_CLIENTS, 2))
        if t % 60 == 0:
            d = diagnostics(problem, state)
            print(f"round {t:4d}  ||grad Phi(x̄)|| = {float(d['phi_grad_norm']):.4f}"
                  f"   consensus Ξx = {float(d['consensus_x']):.2e}")
    return float(diagnostics(problem, state)["phi_grad_norm"])


if __name__ == "__main__":
    g_kgt = run("kgt_minimax")
    g_local = run("local_sgda")
    print(f"\nK-GT-Minimax reaches ||grad|| = {g_kgt:.4f}; "
          f"local SGDA (no tracking) stalls at {g_local:.4f} "
          f"under the same heterogeneity — the paper's DH-robustness claim.")
