"""End-to-end driver: decentralized DRO training of a real transformer LM
with K-GT-Minimax over heterogeneous clients.

Default is a CPU-sized model (~9M params) for a few hundred rounds; pass
``--full`` on real hardware for the ~100M paper-toy config.

  PYTHONPATH=src python examples/robust_lm.py --rounds 200
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS
from repro.launch import train as train_lib

SMALL = ModelConfig(
    name="robust-lm-9m", arch_type="dense", num_layers=4, d_model=256,
    num_heads=4, num_kv_heads=2, d_ff=1024, vocab_size=4096,
    tie_embeddings=True, source="this repo (CPU-sized demo)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="use the ~100M paper-toy config (real hardware)")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--alpha", type=float, default=0.2,
                    help="Dirichlet heterogeneity (smaller = more heterogeneous)")
    args_in = ap.parse_args()

    if not args_in.full:
        ARCHS["robust-lm-9m"] = SMALL  # register the demo config

    ns = argparse.Namespace(
        arch="paper-toy" if args_in.full else "robust-lm-9m",
        reduced=False, algorithm="kgt_minimax", rounds=args_in.rounds,
        clients=args_in.clients, local_steps=args_in.local_steps, batch=4,
        seq_len=128, groups=8, mu=1.0, alpha=args_in.alpha, eta_cx=0.02,
        eta_cy=0.15, eta_s=0.5, topology="ring", mixing_impl="dense",
        gossip_dtype="float32", schedule="wsd", warmup=10, seed=0,
        log_every=10, checkpoint_every=100, checkpoint_dir="/tmp/robust_lm_ckpt",
        # repro.engine chunked execution: one compiled scan per 10 rounds,
        # checkpoints land on chunk boundaries
        engine="scan", chunk=10, mesh="host",
        out="/root/repo/results/robust_lm.json")
    result = train_lib.train(ns)
    import json
    import os

    os.makedirs("/root/repo/results", exist_ok=True)
    with open(ns.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"[robust_lm] wrote {ns.out}")


if __name__ == "__main__":
    main()
