"""Serving demo: batched prefill + autoregressive decode with KV caches /
SSM state, across architecture families (the path the decode dry-run shapes
lower).

  PYTHONPATH=src python examples/serve.py --arch mamba2-1.3b --tokens 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_model_config, reduced
from repro.models import decode_step, forward, init_cache, init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    cfg = reduced(get_model_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    total = args.prompt_len + args.tokens

    shape = ((args.batch, args.prompt_len, cfg.num_codebooks)
             if cfg.num_codebooks else (args.batch, args.prompt_len))
    prompt = jax.random.randint(key, shape, 0, cfg.vocab_size)

    # prefill: run the prompt once, populating caches token-by-token decode
    # style for exactness across families (window caches, SSM state, ...)
    caches = init_cache(cfg, args.batch, total)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, caches = decode_step(params, caches, prompt[:, t:t+1],
                                     jnp.int32(t), cfg)
    print(f"[serve] {cfg.name}: prefill {args.prompt_len} tokens "
          f"in {time.time()-t0:.2f}s")

    # decode loop with sampling
    decoded = []
    tok = None
    t0 = time.time()
    for i in range(args.tokens):
        key, ks = jax.random.split(key)
        flat_logits = logits[:, -1].astype(jnp.float32) / args.temperature
        tok = jax.random.categorical(ks, flat_logits, axis=-1)
        tok = tok[:, None] if not cfg.num_codebooks else tok[:, None, :]
        decoded.append(tok)
        logits, caches = decode_step(params, caches, tok,
                                     jnp.int32(args.prompt_len + i), cfg)
    dt = time.time() - t0
    out = jnp.concatenate(decoded, axis=1)
    print(f"[serve] decoded {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.tokens*args.batch/dt:.1f} tok/s); sample row: "
          f"{out[0].reshape(-1)[:16].tolist()}")


if __name__ == "__main__":
    main()
