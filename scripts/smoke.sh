#!/usr/bin/env bash
# CI smoke entrypoint: fast, hermetic signal that the repo is healthy.
#
#   1. pytest collection-only — import health of every module (the historical
#      failure mode: a broken import takes the whole suite down at collection).
#   2. repro.launch.smoke — the dry-run compile path on 8 fake CPU devices:
#      builds + jit-compiles the K-GT-Minimax train round on a
#      (clients=2, fsdp=2, model=2) mesh and prefill/decode on a
#      (data=4, model=2) mesh, exercising repro.dist shardings end-to-end.
#   3. benchmarks.run gossip — the round-epilogue bench: times the
#      dense/fused/pallas_packed lowerings (incl. the Pallas kernel in
#      interpret mode) and counts collectives on a 4-fake-device clients
#      mesh, so the bench + kernel path can't rot.
#
# Usage: scripts/smoke.sh [--archs ARCH ...]     (default: qwen2-0.5b)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== pytest collection =="
python -m pytest -q --collect-only > /dev/null
echo "collection ok"

echo "== step programs compile on fake CPU mesh =="
python -m repro.launch.smoke "$@"

echo "== gossip round-epilogue bench (fake-device mesh collectives) =="
python -m benchmarks.run gossip

echo "smoke ok"
