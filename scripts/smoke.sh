#!/usr/bin/env bash
# CI smoke entrypoint: fast, hermetic signal that the repo is healthy.
#
#   1. pytest collection-only — import health of every module (the historical
#      failure mode: a broken import takes the whole suite down at collection).
#   2. repro.launch.smoke — the dry-run compile path on 8 fake CPU devices:
#      builds + jit-compiles the K-GT-Minimax train round on a
#      (clients=2, fsdp=2, model=2) mesh and prefill/decode on a
#      (data=4, model=2) mesh, exercising repro.dist shardings end-to-end.
#   3. engine-backed train smokes — a real (tiny) repro.launch.train run on
#      the scan engine, once on plain host jit and once on a 4-fake-device
#      decentralized mesh (scanned chunk with donated sharded state +
#      device-side sampling under GSPMD).  The host run writes a
#      --telemetry-out JSONL which repro.obs.report must fold into a
#      summary (nonzero exit on an empty/malformed artifact).
#   4. repro.sweep.run smoke — a tiny 2-seed x 2-heterogeneity sweep
#      end-to-end on the batched (vmapped-cell) path, including the
#      results/sweeps/smoke.json store write.  Then the same sweep twice
#      against a fresh persistent compile cache (repro.sweep.cache): the
#      warm rerun must spend <10% of its wall clock in compile_s, or the
#      cache has regressed.
#   5. sparse-gossip smoke — compile + one mixing_impl=sparse_packed round
#      at n=256 with the clients dim sharded over 4 fake devices, holding
#      the Σc=0 tracking invariant (benchmarks.bench_scale --smoke).
#   6. adversary smoke — compile + one Byzantine trimmed_mean round at n=8
#      under a sign-flip attacker: honest clients stay finite, an all-honest
#      adversary extra is bit-identical to the plain step, and the robust
#      reduce matches the kernels.ref oracle (bench_adversary --smoke).
#   7. fused-round smoke — the whole-round Pallas kernel: the
#      interpret-vs-oracle parity tests (tests/test_fused_round.py) plus
#      bench_gossip --smoke, which times every round lowering (including
#      dense_round vs fused_round on the quadratic workload) and checks the
#      pallas_packed interpret/xla parity row.
#   8. benchmarks.run --benches scale,engine — the clients-axis scaling
#      bench (sparse edge-proportional cost up to n=4096, sub-quadratic
#      slope) and the engine bench (rounds/s: per-round host dispatch vs
#      scanned chunks), merged into results/benchmarks.json.  (`benchmarks
#      .run sweep` runs the heavier batched-vs-sequential sweep bench, and
#      plain `benchmarks.run gossip` the full gossip bench with the
#      collectives subprocess + block_d autotune; both are registered but
#      not part of the smoke.)
#
# Usage: scripts/smoke.sh [--archs ARCH ...]     (default: qwen2-0.5b)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== pytest collection =="
python -m pytest -q --collect-only > /dev/null
echo "collection ok"

echo "== property suite (must collect and pass with 0 skips) =="
# CI path: install the [dev] extra's hypothesis; offline the suite still
# runs — and must still fully pass — on the bundled fallback
# (repro.testing.minihypothesis via tests/_hyp.py).
if ! python -c "import hypothesis" 2>/dev/null; then
    pip install --quiet hypothesis 2>/dev/null \
        || echo "[smoke] offline: property tests run on the bundled fallback"
fi
prop_summary=$(python -m pytest -q tests/test_property.py | tail -n 1)
echo "property suite: ${prop_summary}"
# pytest exits 5 (collected nothing) or 1 (failures) above; these guards
# additionally fail the smoke on skips sneaking back in
if ! echo "${prop_summary}" | grep -q "passed"; then
    echo "FAIL: property suite collected zero hypothesis tests"; exit 1
fi
if echo "${prop_summary}" | grep -q "skipped"; then
    echo "FAIL: property suite must run with zero skips"; exit 1
fi

echo "== step programs compile on fake CPU mesh =="
python -m repro.launch.smoke "$@"

echo "== engine-backed train smoke (host) + telemetry artifact =="
telemetry_out="$(mktemp -d)/train.jsonl"
python -m repro.launch.train --arch qwen2-0.5b --reduced --engine scan \
    --rounds 4 --chunk 2 --clients 2 --local-steps 2 --batch 2 \
    --seq-len 32 --groups 4 --log-every 2 --telemetry-out "${telemetry_out}"
# repro.obs.report exits nonzero on a missing/empty/malformed JSONL — the
# CI check that telemetry-producing runs stay well-formed
python -m repro.obs.report "${telemetry_out}"

echo "== engine-backed train smoke (decentralized mesh, fake devices) =="
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
python -m repro.launch.train --arch qwen2-0.5b --reduced --engine scan \
    --mesh decentralized --rounds 4 --chunk 2 --clients 4 --local-steps 2 \
    --batch 2 --seq-len 32 --groups 4 --log-every 2

echo "== tiny sweep end-to-end (batched cell + store write) =="
python -m repro.sweep.run smoke

echo "== compile cache: warm rerun must spend <10% of wall in compile =="
# the same smoke sweep twice against one fresh cache dir: the first run
# populates it, the second must serve every executable from disk — the
# regression gate for the persistent compile cache (repro.sweep.cache)
cache_dir="$(mktemp -d)"
cache_out="$(mktemp -d)"
REPRO_COMPILE_CACHE="${cache_dir}" python -m repro.sweep.run smoke --out "${cache_out}"
REPRO_COMPILE_CACHE="${cache_dir}" python -m repro.sweep.run smoke --out "${cache_out}"
python - "${cache_out}/smoke.json" <<'PY'
import json, sys
cells = json.load(open(sys.argv[1]))["cells"].values()
compile_s = sum(c["compile_s"] for c in cells)
wall_s = sum(c["wall_s"] for c in cells)
frac = compile_s / wall_s if wall_s else 0.0
print(f"warm sweep: compile_s={compile_s:.3f} wall_s={wall_s:.3f} "
      f"fraction={frac:.1%}")
if frac > 0.10:
    sys.exit(f"FAIL: warm compile fraction {frac:.1%} > 10% — "
             "the compile cache is not being hit")
PY
rm -rf "${cache_dir}" "${cache_out}"

echo "== sparse-gossip smoke (one sparse_packed round at n=256, 4 fake devices) =="
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
python -m benchmarks.bench_scale --smoke

echo "== adversary smoke (one Byzantine trimmed_mean round, sign-flip attacker) =="
python -m benchmarks.bench_adversary --smoke

echo "== fused-round smoke (kernel parity + round-lowering bench) =="
python -m pytest -q tests/test_fused_round.py
python -m benchmarks.bench_gossip --smoke

echo "== scale + engine benches (merged into results/benchmarks.json) =="
python -m benchmarks.run --benches scale,engine

echo "smoke ok"
