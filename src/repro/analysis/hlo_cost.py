"""Loop-aware cost extraction from compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body* once, which
undercounts scanned programs (layer stacks, K local steps, CE chunks) by the
trip count.  This module parses the HLO module text instead:

  * builds the computation call graph (while bodies/conditions, fusions,
    calls) with multiplicities — while trip counts recovered from the loop
    condition's comparison constant (JAX scans: induction 0..N, LT bound);
  * dot FLOPs from output shape x contracted-dim sizes (2·|out|·Πc);
  * HBM traffic approximated as Σ (operand + output bytes) over executable
    (non-fused-body) ops — a fusion reads its inputs and writes its output
    once, which is exactly the post-fusion traffic model;
  * per-collective-kind byte totals (output shape bytes per device).

All numbers are per device (the compiled module is the per-device SPMD
program).  Used by the dry-run and the roofline report.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?)|\w+\[\])\s*"
    r"([\w\-]+)\("
)
_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
# Operand references inside an op's argument list: "%name" tokens.  The
# argument list cannot be comma-split naively — inline operand types like
# f32[128,96]{1,0} contain commas.
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "add-dependency",
}


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class OpInfo:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpInfo]


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], str, Dict[str, str]]:
    """Returns (computations, entry_name, value_shapes name->type_str)."""
    comps: Dict[str, Computation] = {}
    shapes: Dict[str, str] = {}
    entry = ""
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        hm = _HEADER_RE.match(line)
        if hm and "=" not in s.split("(")[0]:
            cur = Computation(name=hm.group(1), ops=[])
            comps[cur.name] = cur
            if s.startswith("ENTRY"):
                entry = cur.name
            # parameter shapes from the header
            for pm in re.finditer(r"%?([\w.\-]+):\s*((?:\([^)]*\))|\w+\[[\d,]*\](?:\{[^}]*\})?)", s):
                shapes.setdefault(pm.group(1), pm.group(2))
            continue
        if s == "}":
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if dm and cur is not None:
            op = OpInfo(name=dm.group(1), type_str=dm.group(2), opcode=dm.group(3),
                        line=s)
            cur.ops.append(op)
            shapes[op.name] = op.type_str
    return comps, entry, shapes


def _operand_names(arglist: str) -> List[str]:
    """Operand value names from an op's argument list, in order."""
    names = _OPERAND_NAME_RE.findall(arglist)
    if names:
        return names
    # fallback for dumps that omit the % sigil (no inline types there)
    return [t.strip().split(" ")[-1] for t in arglist.split(",") if t.strip()]


def _dot_flops(op: OpInfo, shapes: Dict[str, str]) -> float:
    out_elems = 0
    for dt, dims in _shape_dims(op.type_str):
        n = 1
        for d in dims:
            n *= d
        out_elems += n
    m = _OPERANDS_RE.search(op.line.split("=", 1)[1])
    if not m:
        return 0.0
    names = _operand_names(m.group(1))
    lhs = names[0] if names else None
    cm = _CONTRACT_RE.search(op.line)
    contract = 1
    if lhs and cm is not None and lhs in shapes:
        dims_l = _shape_dims(shapes[lhs])
        if dims_l:
            _, ldims = dims_l[0]
            for idx in (int(i) for i in cm.group(1).split(",") if i):
                if idx < len(ldims):
                    contract *= ldims[idx]
    return 2.0 * out_elems * contract


def _while_trip(cond: Computation) -> int:
    consts = []
    for op in cond.ops:
        consts += [int(v) for v in _CONST_RE.findall(op.line)]
    return max(consts) if consts else 1


@dataclasses.dataclass
class CostSummary:
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0
    transcendental_elems: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "exponential-minus-one",
                   "log-plus-one", "erf"}


def analyze(hlo_text: str) -> CostSummary:
    comps, entry, shapes = parse_module(hlo_text)
    if not entry:
        return CostSummary()

    # computation multiplicities via DFS from entry
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        for op in comp.ops:
            called = _CALLED_RE.findall(op.line)
            if not called:
                continue
            if op.opcode == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                cm2 = re.search(r"condition=%?([\w.\-]+)", op.line)
                body = bm.group(1) if bm else None
                cond = cm2.group(1) if cm2 else None
                trips = _while_trip(comps[cond]) if cond in comps else 1
                if cond:
                    visit(cond, m * (trips + 1))
                if body:
                    visit(body, m * trips)
            else:
                for group in called:
                    for cn in group.split(","):
                        visit(cn.strip().lstrip("%"), m)

    visit(entry, 1.0)

    # fused-body computations execute as part of their fusion op: their
    # internal ops contribute FLOPs/transcendentals but NOT HBM traffic.
    fused_bodies = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", op.line)
                if fm:
                    fused_bodies.add(fm.group(1))

    out = CostSummary()
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        in_fusion = comp.name in fused_bodies
        for op in comp.ops:
            opc = op.opcode
            if opc in ("dot", "dot-general", "convolution"):
                out.dot_flops += m * _dot_flops(op, shapes)
            if opc in _TRANSCENDENTAL:
                elems = sum(
                    int(np_prod(dims)) for _, dims in _shape_dims(op.type_str))
                out.transcendental_elems += m * elems
            base = opc.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not opc.endswith("-done"):
                out.collective_bytes[base] += m * _shape_bytes(op.type_str)
                out.collective_counts[base] += m
            if not in_fusion and opc not in _SKIP_OPS and not opc.endswith("-done"):
                # HBM traffic: output + operands
                b = _shape_bytes(op.type_str)
                ops_m = _OPERANDS_RE.search(op.line.split("=", 1)[1])
                if ops_m:
                    for nm in _operand_names(ops_m.group(1)):
                        if nm in shapes:
                            b += _shape_bytes(shapes[nm])
                out.traffic_bytes += m * b
    return out


def np_prod(dims):
    n = 1
    for d in dims:
        n *= d
    return n
