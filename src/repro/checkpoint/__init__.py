from repro.checkpoint.checkpoint import latest, load_metadata, restore, save  # noqa: F401
