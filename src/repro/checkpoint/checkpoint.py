"""Flat-npz checkpointing of full round state (no orbax in this environment).

Pytrees are flattened to path-keyed arrays; restore rebuilds into the given
template (shapes/dtypes validated).  Handles the KGTState dataclass, nested
dicts/tuples, and scalar metadata.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat, _ = _flatten(tree)
    arrays = {f"leaf_{i:05d}": np.asarray(x) for i, x in enumerate(flat)}
    tmp = path + ".tmp.npz"
    np.savez(tmp, **arrays)
    os.replace(tmp, path)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f)


def restore(path: str, template: Any) -> Any:
    flat_t, treedef = _flatten(template)
    with np.load(path) as z:
        flat = [z[f"leaf_{i:05d}"] for i in range(len(flat_t))]
    for i, (a, t) in enumerate(zip(flat, flat_t)):
        ts = np.shape(t)
        if tuple(a.shape) != tuple(ts):
            raise ValueError(f"leaf {i}: checkpoint shape {a.shape} != template {ts}")
    import jax.numpy as jnp

    flat = [jnp.asarray(a, dtype=np.asarray(t).dtype) for a, t in zip(flat, flat_t)]
    return jax.tree.unflatten(treedef, flat)


def load_metadata(path: str) -> dict:
    with open(path + ".meta.json") as f:
        return json.load(f)


def latest(ckpt_dir: str) -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    cands = sorted(
        f for f in os.listdir(ckpt_dir) if f.endswith(".npz") and not f.endswith(".tmp.npz")
    )
    return os.path.join(ckpt_dir, cands[-1]) if cands else None
