from repro.configs.base import (  # noqa: F401
    AlgorithmConfig,
    InputShape,
    MeshConfig,
    MinimaxConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    RunConfig,
    SSMConfig,
    TrainConfig,
)
