"""Configuration dataclasses for the K-GT-Minimax framework.

Everything that defines a run — model architecture, minimax objective, the
K-GT-Minimax algorithm hyperparameters, mesh/sharding layout, and input shape —
is a frozen dataclass here.  Arch files under ``repro/configs/`` instantiate
``ModelConfig`` with the exact assigned specs; ``repro/configs/shapes.py`` holds
the four assigned input shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model architecture
# ---------------------------------------------------------------------------

# Block kinds a decoder stack may be composed of.
BLOCK_ATTN = "attn"            # full causal self-attention + MLP
BLOCK_SLIDING = "sliding"      # sliding-window causal attention + MLP
BLOCK_MOE = "moe"              # attention + MoE MLP
BLOCK_SSM = "ssm"              # Mamba2 SSD block (attention-free)
BLOCK_RGLRU = "rglru"          # RG-LRU recurrent block (Griffin/Hawk style)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 2
    # d_ff of EACH expert (assigned configs give the per-expert width).
    expert_d_ff: int = 0
    router_aux_coef: float = 0.01
    router_jitter: float = 0.0
    capacity_factor: float = 1.25
    dispatch: str = "dense"  # "dense" (one-hot capacity) | "sorted" (ragged_dot)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""
    d_state: int = 128
    d_head: int = 64           # P in the SSD paper
    expand: int = 2            # d_inner = expand * d_model
    chunk: int = 64            # SSD chunk length
    d_conv: int = 4            # depthwise conv width


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RG-LRU (RecurrentGemma) configuration."""
    lru_width: int = 0         # 0 -> d_model
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("rglru", "rglru", "attn_local")
    local_window: int = 2048


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # Block pattern; if empty, derived from arch_type (all-attn / all-moe / ...).
    block_pattern: Tuple[str, ...] = ()
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    rglru: RGLRUConfig = RGLRUConfig()
    # Sliding-window size used when a "sliding" block is selected (also the
    # beyond-paper long-context variant for dense archs).
    sliding_window: int = 4096
    # When > 0, full-attention blocks (attn/moe) switch to this sliding window
    # — the long_500k variant for otherwise-quadratic archs (see DESIGN.md §5).
    long_context_window: int = 0
    # Modality frontend stub: number of prefix embedding tokens supplied by
    # input_specs() (vlm: vision patches; 0 = none).
    num_prefix_tokens: int = 0
    # Audio: number of parallel codebook streams (musicgen).
    num_codebooks: int = 0
    # Source citation for the assigned config.
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    def blocks(self) -> Tuple[str, ...]:
        """Per-layer block kinds, length == num_layers."""
        if self.block_pattern:
            pat = self.block_pattern
        elif self.arch_type == "moe":
            pat = (BLOCK_MOE,)
        elif self.arch_type == "ssm":
            pat = (BLOCK_SSM,)
        elif self.arch_type == "hybrid":
            pat = self.rglru.block_pattern
        else:
            pat = (BLOCK_ATTN,)
        reps = -(-self.num_layers // len(pat))
        return (pat * reps)[: self.num_layers]

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for kind in self.blocks():
            if kind in (BLOCK_ATTN, BLOCK_SLIDING, BLOCK_MOE):
                attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
                if self.qkv_bias:
                    attn += (n_q + 2 * n_kv) * hd
                total += attn
                if kind == BLOCK_MOE:
                    m = self.moe
                    total += d * m.num_experts  # router
                    total += m.num_experts * 3 * d * m.expert_d_ff
                else:
                    total += 3 * d * self.d_ff  # gate/up/down
                total += 2 * d  # norms
            elif kind == BLOCK_SSM:
                s = self.ssm
                d_in = s.expand * d
                nheads = d_in // s.d_head
                total += d * (2 * d_in + 2 * s.d_state + nheads)  # in_proj-ish
                total += d_in * d  # out_proj
                total += d_in * s.d_conv + 2 * nheads + d  # conv, A, D, norm
            elif kind == "attn_local":
                attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
                total += attn + 3 * d * self.d_ff + 2 * d
            elif kind == BLOCK_RGLRU:
                w = self.rglru.lru_width or d
                total += d * w * 2 + w * d  # in (x,gate) + out
                total += 3 * w  # recurrent/input gates diag-ish + Λ
                total += 3 * d * self.d_ff + 2 * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if self.arch_type != "moe":
            return self.param_count()
        m = self.moe
        dense_like = self.param_count()
        n_moe = sum(1 for k in self.blocks() if k == BLOCK_MOE)
        unused = n_moe * (m.num_experts - m.top_k) * 3 * self.d_model * m.expert_d_ff
        return dense_like - unused


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# ---------------------------------------------------------------------------
# Minimax objective
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MinimaxConfig:
    objective: str = "dro"     # quadratic | dro | adversarial
    # DRO: number of loss groups (= d_y); strong-concavity modulus mu.
    num_groups: int = 8
    mu: float = 1.0
    # adversarial: perturbation scale / dims handled by objective impl.
    adv_scale: float = 0.1


# ---------------------------------------------------------------------------
# K-GT-Minimax algorithm hyperparameters (Algorithm 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AlgorithmConfig:
    algorithm: str = "kgt_minimax"  # kgt_minimax | dsgda | local_sgda | gt_gda
    num_clients: int = 4
    local_steps: int = 2            # K
    eta_cx: float = 1e-3            # local stepsize for x
    eta_cy: float = 1e-2            # local stepsize for y
    eta_sx: float = 1.0             # communication stepsize for x
    eta_sy: float = 1.0             # communication stepsize for y
    topology: str = "ring"          # ring | torus | full | exp | star
    # Gossip implementation: "dense" (faithful W-einsum), "ring" (ppermute),
    # "fused_dense"/"fused_ring" (pack Delta+params into one collective per
    # leaf), "pallas_packed" (ravel the whole state into one (n, D) buffer and
    # run the fused gossip/correction/mixing epilogue in a single pass —
    # see repro.core.packing + repro.kernels.gossip), or "sparse_packed"
    # (same fused packed epilogue, but W is padded-CSR neighbor lists and
    # gossip is a neighbor-row gather — O(n·max_deg·D), never an (n, n)
    # array; the scaling path for num_clients ≳ 512, see
    # repro.core.sparse_topology + repro.kernels.neighbor_gossip).
    mixing_impl: str = "dense"
    # Backend for the pallas_packed/sparse_packed epilogue: "auto" (Pallas
    # kernel on TPU, packed-xla oracle elsewhere), "pallas", "interpret",
    # or "xla".
    gossip_backend: str = "auto"
    gossip_dtype: str = "float32"   # beyond-paper: "bfloat16" halves gossip bytes
    # Error-feedback compressed gossip (Sun & Wei's communication-efficient
    # federated minimax line): quantize the transmitted round delta with a
    # deterministic quantizer ("bf16" | "int8") and carry the quantization
    # residual as per-client EF state (KGTState.ef_x/ef_y).  None = exact.
    # Valid only for the packed lowerings (mixing_impl "pallas_packed" /
    # "fused_round") — the per-leaf impls have no packed buffer to quantize.
    gossip_compress: Optional[str] = None
    # Inner optimizer applied to local steps ("sgd" is the faithful Algorithm 1).
    inner_opt: str = "sgd"
    # Correction-state dtype: bfloat16 halves tracking-state memory (the
    # internvl2 memory lever in EXPERIMENTS.md §Perf); float32 is faithful.
    correction_dtype: str = "float32"
    # Time-varying gossip: cycle through these topologies round-robin
    # (e.g. ("ring", "exp")); empty = static cfg.topology.  Covered by the
    # changing-topology analysis of [KLB+20] the paper builds on.
    topology_cycle: Tuple[str, ...] = ()
    # --- stochastic topologies + partial participation (beyond-paper churn
    # axes, repro.core.stochastic_topology).  The family is a static program
    # property; the rates are traced scalars.  "static" + participation_rate
    # 1.0 = the paper's fixed-W/full-participation setting.
    topology_family: str = "static"   # static | erdos_renyi | pairwise | dropout
    edge_prob: float = 0.5            # erdos_renyi: P[link present] per round
    client_drop_prob: float = 0.3     # dropout family: P[client drops links]
    participation_rate: float = 1.0   # < 1: per-round Bernoulli client mask
    topology_seed: int = 0            # seeds the W/mask sampling streams
    # --- Byzantine adversary axis (repro.core.adversary).  num_byzantine
    # clients (the first f client slots) corrupt their *outgoing* Δ each
    # round per `attack`; honest clients are untouched.  Defending requires
    # a robust mixing_impl ("coord_median"/"trimmed_mean" and their
    # sparse_* forms) — plain gossip averages the poison in.  `robust_trim`
    # is the number of extreme values trimmed per side by trimmed_mean.
    num_byzantine: int = 0
    attack: str = "honest"            # honest | sign_flip | large_norm | random_noise
    attack_scale: float = 1.0         # attack magnitude multiplier
    robust_trim: int = 1              # trimmed_mean: values trimmed per side


# ---------------------------------------------------------------------------
# Mesh / sharding
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    num_clients: int = 4       # clients axis of the logical mesh
    fsdp: int = 4
    model: int = 16
    # Parameter sharding mode within a client: "fsdp2d" shards params over
    # (fsdp, model); "replicated" keeps them client-replicated (small models).
    param_mode: str = "fsdp2d"
    moe_expert_parallel: bool = False
    # shard attention heads over 'model' via all-to-all instead of
    # all-gathering the seq-sharded residual (Megatron-SP style switch)
    attn_heads_sharding: bool = False
    # residual sharding: "batch_seq" (fsdp, model) or "batch" (fsdp only)
    residual_mode: str = "batch_seq"
    remat: bool = True

    @property
    def devices_needed(self) -> int:
        return self.num_clients * self.fsdp * self.model


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainConfig:
    rounds: int = 100
    seed: int = 0
    dtype: str = "bfloat16"         # activations/compute dtype
    param_dtype: str = "float32"
    schedule: str = "constant"      # constant | cosine | wsd
    warmup_rounds: int = 10
    decay_start_frac: float = 0.8   # WSD stable->decay point
    log_every: int = 10
    checkpoint_every: int = 0       # 0 = off
    checkpoint_dir: str = "/tmp/repro_ckpt"


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: InputShape
    minimax: MinimaxConfig = MinimaxConfig()
    algo: AlgorithmConfig = AlgorithmConfig()
    mesh: MeshConfig = MeshConfig()
    train: TrainConfig = TrainConfig()
