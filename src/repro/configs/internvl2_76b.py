"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256,
InternViT vision frontend (stubbed: input_specs() provides patch embeddings) +
InternLM2/Llama3-70B-like language backbone.  [arXiv:2404.16821]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    num_prefix_tokens=256,  # vision patch embeddings per image (stub frontend)
    source="arXiv:2404.16821",
)
