"""mamba2-1.3b [ssm] — 48L d_model=2048 attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_head=64, expand=2, chunk=64, d_conv=4),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
