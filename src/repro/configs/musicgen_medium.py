"""musicgen-medium [audio] — 48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048,
decoder-only transformer over EnCodec tokens (4 codebooks, delay pattern at the
data layer; EnCodec itself stubbed per the brief).  [arXiv:2306.05284]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    num_codebooks=4,
    source="arXiv:2306.05284",
)
