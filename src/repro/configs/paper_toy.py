"""paper-toy — a ~100M llama-like config used for the paper-faithful end-to-end
training experiments (the paper itself is architecture-agnostic theory; this is
the repo's default 'small real model' for V1-V6 style runs at model scale).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-toy",
    arch_type="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=32000,
    tie_embeddings=True,
    source="this repo (paper has no model experiments)",
)
