"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936,
GQA + QKV bias.  [arXiv:2407.10671]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    source="arXiv:2407.10671",
)
