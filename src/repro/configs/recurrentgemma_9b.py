"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000, RG-LRU + local attention in a 2:1 pattern (two recurrent blocks per
local-attention block), window 2048.  [arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    rglru=RGLRUConfig(
        lru_width=4096,
        block_pattern=("rglru", "rglru", "attn_local"),
        local_window=2048,
    ),
    source="arXiv:2402.19427",
)
