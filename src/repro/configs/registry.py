"""Registry of assigned architectures (+ the repo's paper-toy model).

``get_model_config(arch_id)`` returns the full assigned config;
``reduced(cfg)`` returns the CPU-smoke-test variant (2 layers, d_model<=512,
<=4 experts) of the same family, per the brief.
"""
from __future__ import annotations

import dataclasses

from repro.configs import (
    granite_moe_1b_a400m,
    internvl2_76b,
    mamba2_1_3b,
    minicpm_2b,
    musicgen_medium,
    paper_toy,
    qwen1_5_32b,
    qwen1_5_4b,
    qwen2_0_5b,
    qwen3_moe_30b_a3b,
    recurrentgemma_9b,
)
from repro.configs.base import ModelConfig, MoEConfig, RGLRUConfig, SSMConfig

ARCHS = {
    "granite-moe-1b-a400m": granite_moe_1b_a400m.CONFIG,
    "minicpm-2b": minicpm_2b.CONFIG,
    "qwen2-0.5b": qwen2_0_5b.CONFIG,
    "recurrentgemma-9b": recurrentgemma_9b.CONFIG,
    "mamba2-1.3b": mamba2_1_3b.CONFIG,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b.CONFIG,
    "qwen1.5-32b": qwen1_5_32b.CONFIG,
    "internvl2-76b": internvl2_76b.CONFIG,
    "qwen1.5-4b": qwen1_5_4b.CONFIG,
    "musicgen-medium": musicgen_medium.CONFIG,
    "paper-toy": paper_toy.CONFIG,
}

ASSIGNED = tuple(k for k in ARCHS if k != "paper-toy")


def get_model_config(arch_id: str) -> ModelConfig:
    try:
        return ARCHS[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}") from None


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests.

    2 layers (enough to cover the hybrid block pattern we truncate to 3),
    d_model <= 512, <= 4 experts, small vocab.
    """
    d = min(cfg.d_model, 256)
    n_heads = max(2, min(cfg.num_heads, 4)) if cfg.num_heads else 0
    n_kv = max(1, min(cfg.num_kv_heads, n_heads)) if cfg.num_kv_heads else 0
    if n_heads:
        while n_heads % max(n_kv, 1):
            n_kv -= 1
    num_layers = 3 if cfg.arch_type == "hybrid" else 2
    changes = dict(
        num_layers=num_layers,
        d_model=d,
        num_heads=n_heads,
        num_kv_heads=n_kv,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=(d // n_heads) if n_heads else 0,
        sliding_window=64,
        num_prefix_tokens=min(cfg.num_prefix_tokens, 4),
    )
    if cfg.arch_type == "moe":
        changes["moe"] = MoEConfig(
            num_experts=4, top_k=2, expert_d_ff=64,
            router_aux_coef=cfg.moe.router_aux_coef,
        )
    if cfg.arch_type == "ssm":
        changes["ssm"] = SSMConfig(d_state=16, d_head=32, expand=2, chunk=16, d_conv=4)
    if cfg.arch_type == "hybrid":
        changes["rglru"] = RGLRUConfig(
            lru_width=d, conv_width=4,
            block_pattern=cfg.rglru.block_pattern, local_window=32,
        )
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **changes)
