from repro.core.kgt_minimax import (  # noqa: F401
    KGTState,
    diagnostics,
    init_state,
    make_round_step,
    mean_over_clients,
)
from repro.core.minimax import MinimaxProblem  # noqa: F401
from repro.core.mixing import consensus_error, make_mixer, mix_dense, mix_ring  # noqa: F401
from repro.core.objectives import (  # noqa: F401
    adversarial_problem,
    dro_problem,
    make_quadratic_data,
    quadratic_problem,
)
from repro.core.topology import mixing_matrix, spectral_gap  # noqa: F401
