from repro.core.adversary import (  # noqa: F401
    ATTACK_IDS,
    ATTACK_STREAM,
    ATTACKS,
    Adversary,
    apply_attack,
    attack_ids,
    make_attack_sampler,
)
from repro.core.kgt_minimax import (  # noqa: F401
    KGTState,
    diagnostics,
    init_state,
    make_round_step,
    mean_over_clients,
    point_etas,
)
from repro.core.minimax import MinimaxProblem  # noqa: F401
from repro.core.mixing import (  # noqa: F401
    MIXING_IMPLS,
    ROBUST_IMPLS,
    ROBUST_RULES,
    consensus_error,
    make_mixer,
    mix_dense,
    mix_packed,
    mix_ring,
    robust_mix_dense,
    robust_mix_packed,
    robust_mix_sparse,
)
from repro.core.packing import PackSpec, pack, pack_spec, unpack  # noqa: F401
from repro.core.objectives import (  # noqa: F401
    adversarial_problem,
    dro_problem,
    make_quadratic_data,
    quadratic_cell_problem,
    quadratic_problem,
)
from repro.core.sparse_topology import (  # noqa: F401
    SparseTopology,
    densify,
    from_dense,
    make_sparse_w_sampler,
    sparse_hierarchical,
    sparse_masked_w,
    sparse_mix,
    sparse_mixing_matrix,
)
from repro.core.stochastic_topology import (  # noqa: F401
    DENSE_MATERIALIZATION_LIMIT,
    TOPOLOGY_FAMILIES,
    bernoulli_mask,
    erdos_renyi_w,
    make_participation_sampler,
    make_w_sampler,
    masked_w,
    metropolis_weights,
    pairwise_w,
)
from repro.core.topology import mixing_matrix, spectral_gap  # noqa: F401
