"""Byzantine clients: per-round attacker models riding the extras protocol.

The paper assumes every client honestly follows Algorithm 1; real
decentralized fleets contain hostile participants.  This module opens that
axis the same way the churn axes opened (``repro.core.stochastic_topology``):
the adversary is an **on-device per-round draw** — an :class:`Adversary`
pytree carrying the per-client attacker-id vector and this round's noise
key — produced by a sampler that is a pure function of the round index on
the ``round_stream_key`` fold_in discipline (stream :data:`ATTACK_STREAM`,
disjoint from ``W_STREAM``/``MASK_STREAM`` and the data streams), so a
checkpoint restored at round r replays the identical attack sequence.

Attack models (:data:`ATTACKS`), applied to the attacker's *outgoing*
round update Δ (``kgt_minimax.make_round_step(byzantine=True)`` corrupts
Δ right after the local steps, before gossip/correction/mixing consume it):

* ``honest`` (id 0) — no corruption; honest rows are **bit-untouched** by
  :func:`apply_attack` regardless of which other ids are present;
* ``sign_flip`` (id 1) — sends ``−scale·Δ``: the classic direction-reversal
  attack, deterministic, strongest against plain averaging;
* ``large_norm`` (id 2) — sends the constant vector ``LARGE_NORM·scale``:
  a magnitude outlier, trivially filtered by order statistics but fatal to
  any linear aggregation;
* ``random_noise`` (id 3) — sends ``scale·N(0, I)`` drawn from the round's
  attack key: an uninformative update that poisons averages with variance.

The attacker *follows the protocol with its corrupted Δ*: the attacked
value rides every downstream use (its own correction update included).
Under any doubly stochastic W that relabeling preserves Σ_i c_i = 0 exactly
— an attacked Δ is still just *a* Δ — which is the invariant the property
suite holds plain-gossip rounds to under every attack.  Defending requires
replacing gossip with a robust ``mixing_impl``
(``repro.core.mixing.ROBUST_IMPLS``), which trades that identity for the
honest-subset bounded-drift property (see docs/architecture.md).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import stochastic_topology as stoch_lib

ATTACKS = ("honest", "sign_flip", "large_norm", "random_noise")
ATTACK_IDS = {name: i for i, name in enumerate(ATTACKS)}

# fold_in stream id of the per-round attack-noise draw — disjoint from the
# W/mask streams (1717/2929) and the data sampler's (raw round key, 999).
ATTACK_STREAM = 4242

# the large_norm attack's per-coordinate magnitude (× attack scale)
LARGE_NORM = 100.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Adversary:
    """One round's adversary state, carried as a round-step extra.

    A registered pytree so it flows through jit/scan/vmap like the sampled
    W and participation mask do — the sweep path batches ``ids``/``scale``
    built from traced grid leaves (attacker count, attack id, scale).
    """
    ids: jnp.ndarray    # (n,) int32 per-client attack id (0 = honest)
    key: jnp.ndarray    # this round's PRNG key (random_noise draws)
    scale: jnp.ndarray  # f32 scalar attack magnitude multiplier


def attack_ids(n: int, num_byzantine, attack_id) -> jnp.ndarray:
    """(n,) int32 attacker-id vector: the first ``num_byzantine`` client
    slots carry ``attack_id``, the rest are honest (0).  Both arguments may
    be traced scalars — the sweep grid batches attacker fraction and attack
    type as trajectory leaves."""
    idx = jnp.arange(n, dtype=jnp.int32)
    return jnp.where(idx < num_byzantine,
                     jnp.asarray(attack_id, jnp.int32), jnp.int32(0))


def make_attack_sampler(
    n: int,
    key,
    *,
    num_byzantine,
    attack: str = "sign_flip",
    scale=1.0,
) -> Callable[[jnp.ndarray], Adversary]:
    """``attack_fn(round_idx) -> Adversary`` for the engine's sampler slot
    (``engine.sampler.with_topology(attack_fn=...)``).  The attacker set is
    fixed across rounds (the first ``num_byzantine`` clients); only the
    noise key is per-round, drawn on :data:`ATTACK_STREAM`."""
    if attack not in ATTACK_IDS:
        raise ValueError(f"unknown attack {attack!r}: {ATTACKS}")
    ids = attack_ids(n, num_byzantine, ATTACK_IDS[attack])
    sc = jnp.float32(scale)
    return lambda r: Adversary(
        ids=ids, key=stoch_lib.round_stream_key(key, r, ATTACK_STREAM),
        scale=sc)


def _client_broadcast(v, ndim: int):
    return v.reshape(v.shape + (1,) * (ndim - 1))


def apply_attack(adv: Adversary, tree, *, stream: int = 0):
    """Corrupt the per-client (n, …) leaves of ``tree`` per ``adv.ids``.

    Honest rows (id 0) pass through bit-exactly (they take the untouched
    ``where`` default).  ``stream`` separates the noise draws of different
    variables attacked in the same round (Δx vs Δy); each leaf additionally
    folds its flat index in, so no two leaves share noise.
    """
    key = jax.random.fold_in(adv.key, stream)
    leaves, treedef = jax.tree.flatten(tree)
    scale = adv.scale.astype(jnp.float32)

    def one(i, x):
        m = _client_broadcast(adv.ids, x.ndim)
        x32 = x.astype(jnp.float32)
        noise = scale * jax.random.normal(
            jax.random.fold_in(key, i), x.shape, jnp.float32)
        big = jnp.broadcast_to(LARGE_NORM * scale, x.shape)
        out = jnp.select(
            [m == 1, m == 2, m == 3],
            [-scale * x32, big, noise],
            x32)
        return out.astype(x.dtype)

    return jax.tree.unflatten(
        treedef, [one(i, x) for i, x in enumerate(leaves)])
