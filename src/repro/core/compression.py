"""Error-feedback gossip compression over packed ``(n, D)`` buffers.

Algorithm 1 transmits the round delta Δ twice per variable (the Δ-gossip of
lines 7–8 and, folded into the parameter gossip, η_s·WΔ).  The compressed
path replaces the *transmitted* Δ with its deterministic quantize-dequantize
image and keeps the quantization error as per-client error-feedback state:

    v   = Δ + e                      (delta plus carried residual)
    q   = Q(v)                       (what goes on the wire — bf16 or int8)
    e'  = v − q                      (next round's residual; EXACT in f32,
                                      see repro.kernels.quantize)

Every downstream use of Δ — the correction update ``c += ±(q − Wq)/(K·η_c)``
and the parameter mixing ``θ ← Wθ + η_s·Wq`` — consumes the same ``q``, so
for any doubly stochastic W the Lemma-8 telescoping survives compression
bit-for-bit in expectation and to the f32 noise floor in sum:
Σᵢ(q − Wq)ᵢ = Σq − ΣWq = 0 exactly as for the uncompressed Δ.

Participation composes: an inactive client must put *nothing* on the wire
(its masked Δ is zero but its carried residual generally is not), so the
transmit value is masked to zero and the residual frozen —
``kgt_minimax._freeze_inactive`` then pins the EF leaf bit-exactly like the
rest of the client's state.

The EF residual is a first-class ``KGTState`` leaf (``ef_x``/``ef_y``,
packed ``(n, D)`` f32 in ``core.packing`` layout), so engine chunking,
checkpoint save/restore, and the sweep's vmapped trajectories carry it with
the same bit-identity discipline as (θ, c).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.kernels.quantize import QUANT_METHODS, quantize_dequant

# Config values for AlgorithmConfig.gossip_compress (None = exact gossip).
COMPRESS_METHODS = QUANT_METHODS


def validate_method(method: Optional[str]) -> Optional[str]:
    """None / "none" -> None; otherwise a known quantizer name."""
    if method in (None, "none", ""):
        return None
    if method not in COMPRESS_METHODS:
        raise ValueError(
            f"unknown gossip_compress {method!r}: {COMPRESS_METHODS}")
    return method


def ef_transmit(delta_buf, ef_buf, method: str,
                mask=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(Δ, e) -> (q, e') per the protocol above.  All ``(n, D)`` f32.

    ``mask`` (optional ``(n,)``): inactive rows transmit exact zeros and
    keep their residual unchanged (Δ is already zeroed for them by
    ``_tree_mask_clients``; without the mask their *residual* would leak
    onto the wire).
    """
    v = delta_buf.astype(jnp.float32) + ef_buf.astype(jnp.float32)
    if mask is not None:
        v = v * mask.astype(jnp.float32)[:, None]
    q = quantize_dequant(v, method)
    e_new = v - q
    if mask is not None:
        e_new = jnp.where(mask.astype(bool)[:, None], e_new, ef_buf)
    return q, e_new


def init_ef(n: int, dim: int) -> jnp.ndarray:
    """Zero residual: round 0 transmits Q(Δ) with nothing carried."""
    return jnp.zeros((n, dim), jnp.float32)
