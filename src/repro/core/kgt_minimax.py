"""K-GT-Minimax (Algorithm 1) and its baselines, as pure JAX transforms.

State layout: every variable carries a leading clients dim ``n`` —
``x: (n, …)`` pytree, ``y: (n, …)``, corrections ``cx, cy`` likewise.  The
per-client gradient oracle is vmapped over that dim; on the decentralized
mesh the dim is sharded over the ``clients`` axis so each client's compute
stays on its own sub-mesh and only mixing communicates across clients.

One ``round_step`` = one communication round of Algorithm 1:

  1. K local steps        x_i -= η_cx (∇x F_i + c_i^x);  y_i += η_cy (∇y F_i + c_i^y)
  2. correction update    c_i^x += (Δx_i − (WΔx)_i)/(K η_cx)   [line 7; Σ_j(δ−w)Δx_j]
                          c_i^y −= (Δy_i − (WΔy)_i)/(K η_cy)   [line 8]
  3. parameter mixing     x_i ← Σ_j w_ij (x_j + η_sx Δx_j)     [line 10]
                          y_i ← Σ_j w_ij (y_j + η_sy Δy_j)     [line 11]

Baselines (same harness, for Table-1 comparisons):
  * ``dsgda``      decentralized SGDA: K=1, no tracking  (DM-HSGD-family ancestor)
  * ``local_sgda`` K local steps + mixing, no tracking   (Fed-Norm-SGDA-like)
  * ``gt_gda``     Algorithm 1 with K=1                  (GT-GDA-like)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AlgorithmConfig
from repro.core import adversary as adversary_lib
from repro.core import compression as compression_lib
from repro.core import mixing as mixing_lib
from repro.core import packing
from repro.core import sparse_topology as sparse_lib
from repro.core import stochastic_topology as stoch_lib
from repro.core import topology as topo_lib
from repro.core.minimax import MinimaxProblem
from repro.kernels import ops as kernel_ops


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KGTState:
    x: Any          # (n, …) per-client primal variables
    y: Any          # (n, …) per-client dual variables
    cx: Any         # (n, …) gradient-tracking correction for x
    cy: Any         # (n, …) gradient-tracking correction for y
    round: jnp.ndarray  # scalar int32
    # Error-feedback residuals for compressed gossip (cfg.gossip_compress):
    # packed (n, D) f32 buffers in core.packing layout, one per variable.
    # None (an empty pytree node) when compression is off, so exact-gossip
    # states keep their historical leaf structure — old checkpoints restore
    # unchanged and the engine's template validation sees identical trees.
    ef_x: Any = None
    ef_y: Any = None


def _tree_axpy(a: float, x_tree, y_tree):
    """a * x + y elementwise over pytrees, f32 accumulate, keep y dtype."""
    return jax.tree.map(
        lambda x, y: (a * x.astype(jnp.float32) + y.astype(jnp.float32)).astype(y.dtype),
        x_tree, y_tree)


def _tree_sub(x_tree, y_tree):
    return jax.tree.map(lambda x, y: x - y, x_tree, y_tree)


def _tree_scale(a: float, tree):
    return jax.tree.map(lambda x: (a * x.astype(jnp.float32)).astype(x.dtype), tree)


def _replicate(tree, n: int):
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), tree)


def _client_broadcast(mask, ndim: int):
    """(n,) mask -> (n, 1, …, 1) for broadcasting against an (n, …) leaf."""
    return mask.reshape(mask.shape + (1,) * (ndim - 1))


def _tree_mask_clients(mask, tree):
    """Zero the leaves of inactive clients (mask 0).  ×1.0 in f32 is exact,
    so active clients' values are bit-unchanged."""
    def one(x):
        m = _client_broadcast(mask.astype(jnp.float32), x.ndim)
        return (x.astype(jnp.float32) * m).astype(x.dtype)

    return jax.tree.map(one, tree)


def _freeze_inactive(mask, new_state: "KGTState", old_state: "KGTState"):
    """Per-client select: active clients take the round's result, inactive
    clients keep (θ, c) bit-exactly.  The masked Δ and self-loop W already
    make the inactive rows no-ops mathematically; the where pins them
    bit-exactly regardless of float summation order."""
    def pick(new, old):
        return jax.tree.map(
            lambda a, b: jnp.where(_client_broadcast(mask, a.ndim), a, b),
            new, old)

    return KGTState(
        x=pick(new_state.x, old_state.x),
        y=pick(new_state.y, old_state.y),
        cx=pick(new_state.cx, old_state.cx),
        cy=pick(new_state.cy, old_state.cy),
        round=new_state.round,
        # EF residuals freeze with the rest of the inactive client's state
        # (tree.map over None is a no-op for the uncompressed case)
        ef_x=pick(new_state.ef_x, old_state.ef_x),
        ef_y=pick(new_state.ef_y, old_state.ef_y))


def init_state(
    problem: MinimaxProblem,
    cfg: AlgorithmConfig,
    key,
    init_batch=None,
    init_keys=None,
) -> KGTState:
    """Shared x0/y0 across clients; corrections per the paper's initialization
    c_i = −∇F_i(x0,y0;ξ_i) + (1/n)Σ_j ∇F_j(x0,y0;ξ_j)  (Lemma 8 ⇒ Σ_i c_i = 0).
    For variants without tracking, corrections are zeros.
    """
    n = cfg.num_clients
    kx, ky, kg = jax.random.split(key, 3)
    x0 = problem.init_x(kx)
    y0 = problem.init_y(ky)
    x = _replicate(x0, n)
    y = _replicate(y0, n)

    track = cfg.algorithm in ("kgt_minimax", "gt_gda")
    if track and init_batch is not None:
        keys = init_keys if init_keys is not None else jax.random.split(kg, n)
        gx, gy = jax.vmap(problem.grads)(x, y, init_batch, keys)
        cx = jax.tree.map(lambda g: g.mean(0, keepdims=True) - g, gx)
        cy = jax.tree.map(lambda g: g.mean(0, keepdims=True) - g, gy)
    else:
        cx = jax.tree.map(jnp.zeros_like, x)
        cy = jax.tree.map(jnp.zeros_like, y)
    if cfg.correction_dtype != "float32":
        cd = jnp.dtype(cfg.correction_dtype)
        cx = jax.tree.map(lambda c: c.astype(cd), cx)
        cy = jax.tree.map(lambda c: c.astype(cd), cy)
    ef_x = ef_y = None
    if compression_lib.validate_method(cfg.gossip_compress) is not None:
        # zero EF residual per variable, packed (n, D) — round 0 transmits
        # Q(Δ) with nothing carried
        ef_x = compression_lib.init_ef(n, packing.pack_spec(x).dim)
        ef_y = compression_lib.init_ef(n, packing.pack_spec(y).dim)
    return KGTState(x=x, y=y, cx=cx, cy=cy, round=jnp.int32(0),
                    ef_x=ef_x, ef_y=ef_y)


def point_etas(cfg: AlgorithmConfig) -> dict:
    """The traced-stepsize bundle for ``make_round_step(traced_etas=True)``.

    ``corr_x``/``corr_y`` are the line-7/8 correction scales ±1/(K·η_c),
    precomputed **host-side in float64** — the same Python-float arithmetic
    the static path performs — so a trajectory run with traced etas is
    bit-identical to one compiled with the etas baked in (the in-graph f32
    division ``1/(K·η)`` can differ from the f64 value by an ulp).
    """
    k = 1 if cfg.algorithm in ("dsgda", "gt_gda") else cfg.local_steps
    return {
        "eta_cx": np.float32(cfg.eta_cx),
        "eta_cy": np.float32(cfg.eta_cy),
        "eta_sx": np.float32(cfg.eta_sx),
        "eta_sy": np.float32(cfg.eta_sy),
        "corr_x": np.float32(1.0 / (k * cfg.eta_cx)),
        "corr_y": np.float32(-1.0 / (k * cfg.eta_cy)),
    }


def make_round_step(
    problem: MinimaxProblem,
    cfg: AlgorithmConfig,
    w: Optional[np.ndarray] = None,
    lr_scale: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
    *,
    traced_etas: bool = False,
    traced_w: bool = False,
    participation: bool = False,
    byzantine: bool = False,
):
    """Builds round_step(state, batches, keys) -> state.

    ``batches``: pytree with leading dims (K, n, …) — one per (local step,
    client).  ``keys``: (K, n) PRNG keys.  ``lr_scale``: optional schedule
    multiplier as a function of the round index.

    ``traced_etas=True`` changes the signature to
    ``round_step(state, batches, keys, etas)`` where ``etas`` is the scalar
    bundle of :func:`point_etas` carried as traced values — what lets
    ``repro.sweep`` vmap one compiled program over trajectories that differ
    only in their stepsizes.  The stepsizes in ``cfg`` are ignored on that
    path; compose any schedule into the eta values instead of ``lr_scale``.

    ``traced_w=True`` appends an ``(n, n)`` mixing matrix to the signature:
    W becomes a traced operand of the round — alongside the eta bundle on
    the sweep path — instead of a constant baked into the program, which is
    what lets a per-round *random* topology (``repro.core
    .stochastic_topology``) ride the engine's sampler slot.  ``participation
    =True`` appends an ``(n,)`` per-round client mask: inactive clients run
    no effective local update (their Δ is zeroed), drop every gossip link
    (self-loop fallback, :func:`stochastic_topology.masked_w` applied to
    whatever W the round uses), and their (θ, c) freeze bit-exactly; the
    Σ_i c_i = 0 tracking invariant holds under any mask because the masked
    W stays doubly stochastic.  ``byzantine=True`` appends a
    :class:`repro.core.adversary.Adversary` pytree: each attacker's
    *outgoing* Δ is corrupted right after the local steps — the attacked Δ
    rides every downstream use (gossip, its own correction, mixing), so
    under any doubly stochastic W the Σc = 0 identity survives every attack
    (an attacked Δ is still just a Δ); honest rows are bit-untouched.
    Extras order: ``round_step(state, batches, keys[, etas][, w][, mask]
    [, adversary])``.

    The **robust** ``mixing_impl``\\s (``mixing.ROBUST_IMPLS``:
    ``coord_median`` / ``trimmed_mean`` and their ``sparse_*``
    neighbor-gather forms) defend against those attacks by replacing every
    ``Σ_j w_ij v_j`` with a per-coordinate order statistic over the support
    of this round's W.  The aggregation R is nonlinear, so the parameter
    update becomes the one-pass ``θ ← R(θ + η_s Δ)`` (the linear split
    ``Wθ + η_s WΔ`` no longer exists) and the line-7/8 corrections keep
    their shape, ``c += ±(Δ − R(Δ))/(K η_c)``, but are **not** mean-
    preserving — Σ_i c_i drifts (boundedly, on the honest subset) instead
    of staying 0.  See docs/architecture.md § adversary axis.

    With ``mixing_impl="sparse_packed"`` the mixing matrix is a
    :class:`repro.core.sparse_topology.SparseTopology` everywhere a dense
    (n, n) array would appear: ``w`` may be a ``SparseTopology`` (a dense
    array is bridged via ``from_dense``; omitted, the support is built by
    ``sparse_mixing_matrix(cfg.topology, n)``), the ``traced_w`` extra is a
    ``SparseTopology`` pytree (see ``sparse_topology.make_sparse_w_sampler``),
    participation masking applies ``sparse_masked_w`` to the neighbor lists,
    and the round epilogue runs the neighbor-gather kernel
    (``kernels.ops.sparse_gossip_round``) — O(n·max_deg·D) per round with no
    (n, n) materialization anywhere.
    """
    if traced_etas and lr_scale is not None:
        raise ValueError(
            "traced_etas carries per-trajectory stepsizes; fold the schedule "
            "into the eta values instead of passing lr_scale")
    if cfg.mixing_impl not in mixing_lib.MIXING_IMPLS:
        raise ValueError(
            f"unknown mixing_impl {cfg.mixing_impl!r}: {mixing_lib.MIXING_IMPLS}")
    if cfg.topology_cycle and cfg.mixing_impl.endswith("ring"):
        # the time-varying path lowers gossip densely per round; a
        # neighbor-only ring exchange cannot realize arbitrary cycle members
        raise ValueError(
            f"mixing_impl={cfg.mixing_impl!r} is not supported with "
            "topology_cycle; use 'dense', 'fused_dense', or 'pallas_packed'")
    if traced_w and cfg.topology_cycle:
        raise ValueError(
            "traced_w supplies W per round; topology_cycle would fight it — "
            "drop the cycle (sample the W sequence instead) or traced_w")
    sparse = cfg.mixing_impl == "sparse_packed"
    robust = cfg.mixing_impl in mixing_lib.ROBUST_IMPLS
    sparse_robust = robust and cfg.mixing_impl.startswith("sparse_")
    # sparse_w: W is a SparseTopology everywhere a dense array would appear
    sparse_w = sparse or sparse_robust
    robust_rule = mixing_lib.robust_rule(cfg.mixing_impl) if robust else None
    fused = cfg.mixing_impl == "fused_round"
    compress = compression_lib.validate_method(cfg.gossip_compress)
    if compress and cfg.mixing_impl not in ("pallas_packed", "fused_round"):
        raise ValueError(
            f"gossip_compress={cfg.gossip_compress!r} quantizes the packed "
            f"(n, D) round delta; mixing_impl={cfg.mixing_impl!r} has no "
            "packed buffer — use 'pallas_packed' or 'fused_round'")
    if fused:
        if problem.affine_coeffs is None:
            raise ValueError(
                "mixing_impl='fused_round' runs the K local steps as affine "
                "updates inside the kernel; this problem has no "
                "affine_coeffs oracle — use 'pallas_packed'")
        if byzantine:
            # the attack corrupts the per-leaf Δ tree, which never exists on
            # the whole-round path (Δ is born packed inside the kernel)
            raise ValueError(
                "mixing_impl='fused_round' does not support byzantine; "
                "use 'pallas_packed' (the attack applies pre-packing)")
    if cfg.topology_cycle and (sparse_w or robust):
        # the cycle path stacks dense (n, n) members and lowers them through
        # mix_dense per round; neither the neighbor-list representation nor
        # the robust order-statistic epilogue rides it
        raise ValueError(
            f"mixing_impl={cfg.mixing_impl!r} is not supported with "
            "topology_cycle; use traced_w with a per-round sampler instead")
    dynamic_w = traced_w or participation
    packed = cfg.mixing_impl == "pallas_packed"
    pack_gd = (None if cfg.gossip_dtype in (None, "float32")
               else jnp.dtype(cfg.gossip_dtype))
    if dynamic_w and not packed and not sparse and not robust and not fused:
        # validates the impl (ring-style neighbor exchanges cannot realize a
        # per-round arbitrary W) and gives us mix(tree, w) with w traced
        traced_mix = mixing_lib.make_traced_mixer(
            cfg.mixing_impl, cfg.gossip_dtype)
    if cfg.topology_cycle:
        # time-varying gossip: W selected per round from the cycle
        ws = jnp.stack([
            jnp.asarray(topo_lib.mixing_matrix(t, cfg.num_clients), jnp.float32)
            for t in cfg.topology_cycle])
        gd = pack_gd
        get_w = lambda round_idx: ws[round_idx % len(cfg.topology_cycle)]

        def make_mix(round_idx):
            w_t = get_w(round_idx)
            return lambda tree: mixing_lib.mix_dense(tree, w_t, gossip_dtype=gd)
    else:
        if w is None and not traced_w:
            w = (sparse_lib.sparse_mixing_matrix(cfg.topology, cfg.num_clients)
                 if sparse_w
                 else topo_lib.mixing_matrix(cfg.topology, cfg.num_clients))
        if sparse_w:
            w_arr = (None if w is None
                     else (w if isinstance(w, sparse_lib.SparseTopology)
                           else sparse_lib.from_dense(np.asarray(w))))
        else:
            w_arr = None if w is None else jnp.asarray(w, jnp.float32)
        get_w = lambda round_idx: w_arr
        if packed or sparse_w or robust or dynamic_w or fused:
            make_mix = None  # W is consumed directly, per round
        else:
            static_mix = mixing_lib.make_mixer(
                cfg.topology, cfg.mixing_impl, w, cfg.gossip_dtype)
            make_mix = lambda round_idx: static_mix
    gossip_backend = kernel_ops.resolve_gossip_backend(cfg.gossip_backend)
    algo = cfg.algorithm
    track = algo in ("kgt_minimax", "gt_gda")
    k_steps = 1 if algo in ("dsgda", "gt_gda") else cfg.local_steps
    grads_v = jax.vmap(problem.grads)
    # (K, n)-batched affine-coefficient oracle for the whole-round kernel
    coeffs_v = (jax.vmap(jax.vmap(problem.affine_coeffs)) if fused else None)

    def _fused_round(state: KGTState, batches, keys, w_t, mask,
                     eta_cx, eta_cy, eta_sx, eta_sy, corr_x, corr_y):
        """Whole-round lowering: one kernel call runs the K affine local
        steps AND the gossip epilogue over the packed z = (x; y) state —
        see kernels/fused_round.py.  Requires G constant across the K local
        steps (the quadratic workload: per-client coefficients ride the
        batch unchanged per step, only the noise shift h varies)."""
        spec_x = packing.pack_spec(state.x)
        spec_y = packing.pack_spec(state.y)
        n, dzx, dzy = spec_x.n, spec_x.dim, spec_y.dim
        dz = dzx + dzy
        bat = jax.tree.map(lambda b: b[:k_steps], batches)
        kk = jax.tree.map(lambda b: b[:k_steps], keys)
        g_all, h_all = coeffs_v(bat, kk)          # (K, n, dz, dz), (K, n, dz)
        g_mat = g_all[0]   # G is step-constant; XLA DCEs the dead steps

        def cat(xb, yb):
            return jnp.concatenate([xb, yb], axis=1)

        z0 = cat(packing.pack(state.x, spec_x), packing.pack(state.y, spec_y))
        if track:
            cb = cat(packing.pack(state.cx), packing.pack(state.cy))
        else:
            cb = jnp.zeros((n, dz), jnp.float32)
        if compress:
            if state.ef_x is None:
                raise ValueError(
                    "gossip_compress is set but the state carries no EF "
                    "residual — build it with init_state under the same cfg")
            efb = cat(state.ef_x, state.ef_y)
        else:
            efb = jnp.zeros((n, dz), jnp.float32)
        # per-column vectors: x-block descends, y-block ascends; corr = 0
        # encodes the no-tracking variants (c' = c exactly)
        one_x = jnp.ones((dzx,), jnp.float32)
        one_y = jnp.ones((dzy,), jnp.float32)
        base_step = jnp.concatenate([eta_cx * one_x, -eta_cy * one_y])
        mask_col = (jnp.ones((n, 1), jnp.float32) if mask is None
                    else mask.astype(jnp.float32)[:, None])
        step = mask_col * base_step[None, :]       # inactive ⇒ Δ ≡ 0 exactly
        etas = jnp.broadcast_to(
            jnp.concatenate([eta_sx * one_x, eta_sy * one_y])[None, :],
            (n, dz))
        if track:
            corr = jnp.concatenate([corr_x * one_x, corr_y * one_y])
        else:
            corr = jnp.zeros((dz,), jnp.float32)
        corr = jnp.broadcast_to(corr[None, :], (n, dz))
        mask_full = jnp.broadcast_to(mask_col, (n, dz))
        z_new, c_new, ef_new = kernel_ops.fused_round(
            w_t, z0, cb, efb, g_mat, h_all, step, etas, corr, mask_full,
            backend=gossip_backend, compress=compress,
            gossip_dtype=cfg.gossip_dtype)
        if track:
            cx = packing.unpack(c_new[:, :dzx], packing.pack_spec(state.cx))
            cy = packing.unpack(c_new[:, dzx:], packing.pack_spec(state.cy))
        else:
            cx, cy = state.cx, state.cy
        new_state = KGTState(
            x=packing.unpack(z_new[:, :dzx], spec_x),
            y=packing.unpack(z_new[:, dzx:], spec_y),
            cx=cx, cy=cy, round=state.round + 1,
            ef_x=ef_new[:, :dzx] if compress else state.ef_x,
            ef_y=ef_new[:, dzx:] if compress else state.ef_y)
        return (new_state if mask is None
                else _freeze_inactive(mask, new_state, state))

    def _round(state: KGTState, batches, keys,
               eta_cx, eta_cy, eta_sx, eta_sy, corr_x, corr_y,
               w_t=None, mask=None, adv=None) -> KGTState:
        if packed or sparse_w or robust or dynamic_w or fused:
            if w_t is None:
                w_t = get_w(state.round)
            if mask is not None:
                w_t = (sparse_lib.sparse_masked_w(w_t, mask) if sparse_w
                       else stoch_lib.masked_w(w_t, mask))
            mix = (None if packed or sparse_w or robust or fused
                   else (lambda tree: traced_mix(tree, w_t)))
        else:
            mix = make_mix(state.round)

        if fused:
            # the local steps live inside the kernel — skip the scan below
            return _fused_round(state, batches, keys, w_t, mask,
                                eta_cx, eta_cy, eta_sx, eta_sy,
                                corr_x, corr_y)

        def local_step(carry, inp):
            xx, yy = carry
            batch_k, key_k = inp
            gx, gy = grads_v(xx, yy, batch_k, key_k)
            gx = _tree_axpy(1.0, state.cx, gx) if track else gx   # g + c
            gy = _tree_axpy(1.0, state.cy, gy) if track else gy
            xx = _tree_axpy(-eta_cx, gx, xx)
            yy = _tree_axpy(eta_cy, gy, yy)
            return (xx, yy), None

        # slice exactly k_steps from the provided K-stacked batch
        bat = jax.tree.map(lambda b: b[:k_steps], batches)
        kk = jax.tree.map(lambda b: b[:k_steps], keys)
        (xk, yk), _ = jax.lax.scan(local_step, (state.x, state.y), (bat, kk))

        dx = _tree_sub(xk, state.x)   # Δx = x^{(t)+K} − x^{(t)}
        dy = _tree_sub(yk, state.y)
        if adv is not None:
            # Byzantine corruption of the outgoing Δ: the attacked value
            # rides every use below — gossip, the attacker's own correction,
            # mixing — so the attacker "follows the protocol" with its
            # corrupted update and honest rows stay bit-untouched.  Applied
            # before the participation zeroing so an inactive attacker
            # contributes nothing, exactly like an inactive honest client.
            dx = adversary_lib.apply_attack(adv, dx, stream=0)
            dy = adversary_lib.apply_attack(adv, dy, stream=1)
        if mask is not None:
            # inactive clients contribute no local update: with Δ_i = 0 and
            # W row/col i = e_i (masked_w above), lines 7-11 are no-ops for
            # them and their mass never reaches active clients
            dx = _tree_mask_clients(mask, dx)
            dy = _tree_mask_clients(mask, dy)

        if robust:
            # Robust-aggregation epilogue: R replaces every W contraction.
            # R is nonlinear, so the parameter update is the one-pass
            # θ ← R(θ + η_s Δ) (aggregating the stepped parameters — the
            # linear split Wθ + η_s·WΔ does not exist), and the corrections
            # keep line 7/8's shape c += ±(Δ − R(Δ))/(K η_c) without the
            # Σc = 0 telescoping (R is not doubly stochastic).  W enters
            # only as the support of each client's neighbor set, so
            # participation masking above composes: a masked client's
            # support collapses to {self} and _freeze_inactive pins it.
            def agg(buf):
                if sparse_robust:
                    return mixing_lib.robust_mix_sparse(
                        buf, w_t, rule=robust_rule, trim=cfg.robust_trim,
                        gossip_dtype=pack_gd)
                return mixing_lib.robust_mix_dense(
                    buf, w_t, rule=robust_rule, trim=cfg.robust_trim,
                    gossip_dtype=pack_gd)

            spec_x = packing.pack_spec(state.x)
            spec_y = packing.pack_spec(state.y)
            dxb = packing.pack(dx, spec_x)
            dyb = packing.pack(dy, spec_y)
            xb = agg(packing.pack(state.x, spec_x) + eta_sx * dxb)
            yb = agg(packing.pack(state.y, spec_y) + eta_sy * dyb)
            if track:
                spec_cx = packing.pack_spec(state.cx)
                spec_cy = packing.pack_spec(state.cy)
                cx0 = packing.pack(state.cx, spec_cx)
                cy0 = packing.pack(state.cy, spec_cy)
                cxb = (cx0.astype(jnp.float32)
                       + corr_x * (dxb - agg(dxb))).astype(cx0.dtype)
                cyb = (cy0.astype(jnp.float32)
                       + corr_y * (dyb - agg(dyb))).astype(cy0.dtype)
                cx = packing.unpack(cxb, spec_cx)
                cy = packing.unpack(cyb, spec_cy)
            else:
                cx, cy = state.cx, state.cy
            new_state = KGTState(
                x=packing.unpack(xb, spec_x), y=packing.unpack(yb, spec_y),
                cx=cx, cy=cy, round=state.round + 1)
            return (new_state if mask is None
                    else _freeze_inactive(mask, new_state, state))

        if sparse:
            # Sparse whole-state lowering: same fused epilogue as the packed
            # branch below, but W is padded-CSR neighbor lists and the
            # contraction is a neighbor-row gather — O(n·max_deg·D), no
            # (n, n) array at any point.  See repro.kernels.neighbor_gossip.
            spec_x = packing.pack_spec(state.x)
            spec_y = packing.pack_spec(state.y)
            if not track:
                xb = sparse_lib.sparse_mix(
                    w_t, packing.pack(state.x, spec_x)
                    + eta_sx * packing.pack(dx, spec_x), gossip_dtype=pack_gd)
                yb = sparse_lib.sparse_mix(
                    w_t, packing.pack(state.y, spec_y)
                    + eta_sy * packing.pack(dy, spec_y), gossip_dtype=pack_gd)
                new_state = KGTState(
                    x=packing.unpack(xb, spec_x), y=packing.unpack(yb, spec_y),
                    cx=state.cx, cy=state.cy, round=state.round + 1)
                return (new_state if mask is None
                        else _freeze_inactive(mask, new_state, state))
            spec_cx = packing.pack_spec(state.cx)
            spec_cy = packing.pack_spec(state.cy)
            xb, cxb = kernel_ops.sparse_gossip_round(
                w_t.neighbor_idx, w_t.neighbor_w, w_t.self_w,
                packing.pack(dx, spec_x), packing.pack(state.x, spec_x),
                packing.pack(state.cx, spec_cx), eta_sx, corr_x,
                backend=gossip_backend, gossip_dtype=cfg.gossip_dtype)
            yb, cyb = kernel_ops.sparse_gossip_round(
                w_t.neighbor_idx, w_t.neighbor_w, w_t.self_w,
                packing.pack(dy, spec_y), packing.pack(state.y, spec_y),
                packing.pack(state.cy, spec_cy), eta_sy, corr_y,
                backend=gossip_backend, gossip_dtype=cfg.gossip_dtype)
            new_state = KGTState(
                x=packing.unpack(xb, spec_x),
                y=packing.unpack(yb, spec_y),
                cx=packing.unpack(cxb, spec_cx),
                cy=packing.unpack(cyb, spec_cy),
                round=state.round + 1)
            return (new_state if mask is None
                    else _freeze_inactive(mask, new_state, state))

        if packed:
            # Whole-state lowering: ravel each variable into one (n, D)
            # buffer and run the entire round epilogue (lines 7-11) as one
            # fused pass — θ_new = Wθ + η_s·WΔ and c += ±(Δ − WΔ)/(K·η_c)
            # computed together, one collective per variable instead of one
            # (or two) per leaf.  See repro.kernels.{gossip,ops}.
            spec_x = packing.pack_spec(state.x)
            spec_y = packing.pack_spec(state.y)
            dxb = packing.pack(dx, spec_x)
            dyb = packing.pack(dy, spec_y)
            if compress:
                # EF quantization of the *transmitted* Δ: the same q rides
                # the mixing and the correction below, which preserves the
                # Σc = 0 telescoping (see core.compression).  The residual
                # is per-variable KGTState EF state.
                if state.ef_x is None:
                    raise ValueError(
                        "gossip_compress is set but the state carries no EF "
                        "residual — build it with init_state under the same "
                        "cfg")
                dxb, efx = compression_lib.ef_transmit(
                    dxb, state.ef_x, compress, mask)
                dyb, efy = compression_lib.ef_transmit(
                    dyb, state.ef_y, compress, mask)
            else:
                efx, efy = state.ef_x, state.ef_y
            if not track:
                # no correction state: the epilogue degenerates to a single
                # gossip of the already-stepped parameters, W(θ + η_s·Δ) —
                # don't move (n, D) correction buffers through the kernel
                # just to multiply them by zero
                xb = mixing_lib.mix_dense(
                    packing.pack(state.x, spec_x) + eta_sx * dxb,
                    w_t, gossip_dtype=pack_gd)
                yb = mixing_lib.mix_dense(
                    packing.pack(state.y, spec_y) + eta_sy * dyb,
                    w_t, gossip_dtype=pack_gd)
                new_state = KGTState(
                    x=packing.unpack(xb, spec_x), y=packing.unpack(yb, spec_y),
                    cx=state.cx, cy=state.cy, round=state.round + 1,
                    ef_x=efx, ef_y=efy)
                return (new_state if mask is None
                        else _freeze_inactive(mask, new_state, state))
            spec_cx = packing.pack_spec(state.cx)
            spec_cy = packing.pack_spec(state.cy)
            # pack() builds fresh buffers each round, so their storage can
            # back the kernel outputs (donation is a no-op under jit/CPU —
            # see kernels.ops.fused_gossip_round)
            xb, cxb = kernel_ops.fused_gossip_round(
                w_t, dxb, packing.pack(state.x, spec_x),
                packing.pack(state.cx, spec_cx), eta_sx, corr_x,
                backend=gossip_backend, gossip_dtype=cfg.gossip_dtype,
                donate=True)
            yb, cyb = kernel_ops.fused_gossip_round(
                w_t, dyb, packing.pack(state.y, spec_y),
                packing.pack(state.cy, spec_cy), eta_sy, corr_y,
                backend=gossip_backend, gossip_dtype=cfg.gossip_dtype,
                donate=True)
            new_state = KGTState(
                x=packing.unpack(xb, spec_x),
                y=packing.unpack(yb, spec_y),
                cx=packing.unpack(cxb, spec_cx),
                cy=packing.unpack(cyb, spec_cy),
                round=state.round + 1,
                ef_x=efx, ef_y=efy)
            return (new_state if mask is None
                    else _freeze_inactive(mask, new_state, state))

        # Algorithm 1 communicates two quantities per variable per round:
        # Δ (lines 7-8) and the parameters (lines 10-11).  The faithful
        # implementation issues two gossips; the "fused_*" variants PACK both
        # into one collective per leaf (same bytes, half the collective
        # launches — beyond-paper, bit-identical).
        if cfg.mixing_impl.startswith("fused"):
            def pack_mix(delta, base):
                pairs = jax.tree.map(
                    lambda d, b: jnp.stack([d.astype(jnp.float32),
                                            b.astype(jnp.float32)], axis=1),
                    delta, base)
                mixed = mix(pairs)
                md = jax.tree.map(lambda p: p[:, 0], mixed)
                mb = jax.tree.map(lambda p: p[:, 1], mixed)
                return md, mb

            mdx, mx = pack_mix(dx, state.x)
            mdy, my = pack_mix(dy, state.y)
        else:
            mdx, mdy = mix(dx), mix(dy)
            mx, my = mix(state.x), mix(state.y)

        if track:
            # c^x += (Δx − WΔx)/(K η_cx) ;  c^y −= (Δy − WΔy)/(K η_cy)
            cx = _tree_axpy(corr_x, _tree_sub(dx, mdx), state.cx)
            cy = _tree_axpy(corr_y, _tree_sub(dy, mdy), state.cy)
        else:
            cx, cy = state.cx, state.cy

        # x ← W(x + η_s Δx) = Wx + η_s·WΔx   (second gossip: the parameters)
        x_new = _tree_axpy(eta_sx, mdx, mx)
        y_new = _tree_axpy(eta_sy, mdy, my)

        new_state = KGTState(x=x_new, y=y_new, cx=cx, cy=cy,
                             round=state.round + 1)
        return (new_state if mask is None
                else _freeze_inactive(mask, new_state, state))

    n_extras = int(traced_w) + int(participation) + int(byzantine)
    extras_doc = "".join(
        f"[{name}]" for name, on in (("w", traced_w), ("mask", participation),
                                     ("adversary", byzantine))
        if on)

    def _split_extras(extras):
        if len(extras) != n_extras:
            raise TypeError(
                f"round_step expected {n_extras} extra operand(s) "
                f"{extras_doc or '(none)'} after keys"
                f"{' and etas' if traced_etas else ''}, got {len(extras)}")
        it = iter(extras)
        w_t = next(it) if traced_w else None
        mask = next(it) if participation else None
        adv = next(it) if byzantine else None
        return w_t, mask, adv

    if traced_etas:
        def round_step(state: KGTState, batches, keys, etas,
                       *extras) -> KGTState:
            w_t, mask, adv = _split_extras(extras)
            # η_s = 1 for the no-tracking baselines (plain parameter
            # averaging), exactly like the static path below
            esx = etas["eta_sx"] if track else 1.0
            esy = etas["eta_sy"] if track else 1.0
            return _round(state, batches, keys, etas["eta_cx"], etas["eta_cy"],
                          esx, esy,
                          etas["corr_x"] if track else None,
                          etas["corr_y"] if track else None,
                          w_t=w_t, mask=mask, adv=adv)

        return round_step

    # Communication stepsizes (η_s = 1 for the no-tracking baselines: plain
    # parameter averaging x ← W(x + Δx)).
    eta_sx = cfg.eta_sx if track else 1.0
    eta_sy = cfg.eta_sy if track else 1.0

    def round_step(state: KGTState, batches, keys, *extras) -> KGTState:
        w_t, mask, adv = _split_extras(extras)
        scale = lr_scale(state.round) if lr_scale is not None else 1.0
        eta_cx = cfg.eta_cx * scale
        eta_cy = cfg.eta_cy * scale
        corr_x = 1.0 / (k_steps * eta_cx) if track else None
        corr_y = -1.0 / (k_steps * eta_cy) if track else None
        return _round(state, batches, keys, eta_cx, eta_cy, eta_sx, eta_sy,
                      corr_x, corr_y, w_t=w_t, mask=mask, adv=adv)

    return round_step


# ---------------------------------------------------------------------------
# Diagnostics
# ---------------------------------------------------------------------------

def mean_over_clients(tree):
    return jax.tree.map(lambda x: x.mean(0), tree)


def correction_mean_norm(tree) -> jnp.ndarray:
    """‖c̄‖ = ‖(1/n) Σ_i c_i‖ over all leaves — Lemma 8 says exactly 0 for
    the tracking variants; drift here means the correction update is wrong."""
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(l.mean(0).astype(jnp.float32)))
        for l in jax.tree.leaves(tree)))


def diagnostics(problem: MinimaxProblem, state: KGTState):
    """Exact ‖∇Φ(x̄)‖ (quadratic problems) + consensus errors."""
    out = {
        "consensus_x": mixing_lib.consensus_error(state.x),
        "consensus_y": mixing_lib.consensus_error(state.y),
        # the x-correction norm keeps its historical key; cy is the mirrored
        # line-8 state and deserves the same Lemma-8 watchdog
        "correction_mean_norm": correction_mean_norm(state.cx),
        "correction_mean_norm_y": correction_mean_norm(state.cy),
    }
    if problem.phi_grad is not None:
        xbar = mean_over_clients(state.x)
        out["phi_grad_norm"] = problem.phi_grad_norm(xbar)
    return out
