"""MinimaxProblem: the NC-SC problem abstraction Algorithm 1 optimizes.

A problem supplies per-client value/gradient oracles written for a *single*
client; the algorithm layer vmaps them over the leading clients dim.  The
stochastic oracle receives a per-(round, local-step, client) PRNG key and a
per-client data batch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax


@dataclasses.dataclass(frozen=True)
class MinimaxProblem:
    """NC-SC minimax problem  min_x max_y (1/n) Σ_i f_i(x, y)."""

    # init_x(key) -> x pytree ; init_y(key) -> y pytree (shared across clients)
    init_x: Callable[[Any], Any]
    init_y: Callable[[Any], Any]
    # value(x, y, batch, key) -> scalar f_i(x, y; xi).  The client identity
    # enters through ``batch`` (its data shard) — f_i = f(.; D_i).
    value: Callable[[Any, Any, Any, Any], Any]
    # Optional exact diagnostics (available for the synthetic quadratic):
    # phi_grad(x) -> dPhi/dx of the *global* primal function.
    phi_grad: Optional[Callable[[Any], Any]] = None
    # Optional deterministic full-batch gradient oracle (diagnostics).
    full_grads: Optional[Callable[[Any, Any], Any]] = None
    # Optional affine-gradient coefficient oracle for problems whose per-client
    # stochastic gradient is affine in the packed z = (x; y):
    #   affine_coeffs(batch, key) -> (G, h)  with  (∇x f, ∇y f) = split(G z + h)
    # for a single client (same batch/key semantics as ``grads``, including the
    # noise key split).  The fused-round kernel (kernels/fused_round.py) needs
    # this to run all K local steps in-register; ``None`` means the problem has
    # no affine form and mixing_impl="fused_round" must be rejected.
    affine_coeffs: Optional[Callable[[Any, Any], Any]] = None
    mu: float = 1.0

    def grads(self, x, y, batch, key):
        """(∇x f_i, ∇y f_i) at (x, y) on ``batch`` with noise key ``key``."""
        gx, gy = jax.grad(self.value, argnums=(0, 1))(x, y, batch, key)
        return gx, gy

    def phi_grad_norm(self, x) -> Any:
        assert self.phi_grad is not None, "problem lacks exact Phi oracle"
        g = self.phi_grad(x)
        import jax.numpy as jnp

        return jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(g)))
