"""Gossip mixing operators over pytrees with a leading clients dim.

Two lowering strategies for ``Σ_j w_ij T_j``:

* ``dense`` — einsum with the full (n, n) mixing matrix W.  Faithful to the
  paper (arbitrary topology); under GSPMD the contraction over the sharded
  clients dim lowers to an all-gather of the full tensor, (n-1)·|T| bytes in
  per client.
* ``ring`` — neighbor-only exchange expressed as ``jnp.roll`` along the
  clients dim, which GSPMD lowers to collective-permutes over the clients
  mesh axis (2·|T| bytes in per client).  Valid for the ring topology (and
  any circulant W via repeated shifts).

``gossip_dtype`` optionally downcasts the *communicated* values (beyond-paper
optimization; tracking state stays f32).

Beyond the linear lowerings, the **robust** impls (:data:`ROBUST_IMPLS`)
replace ``Σ_j w_ij T_j`` with a per-coordinate order statistic over each
client's neighbor set — coordinate-wise median or b-trimmed mean over
``{j : w_ij > 0} ∪ {self}`` — the Byzantine-tolerant aggregation of
robust decentralized learning (Ghiasvand et al., PAPERS.md).  They consume
W only as a *support* (which neighbors count), are **nonlinear** (so not
doubly stochastic: Σ_i R(T)_i ≠ Σ_i T_i in general), and compose with
participation masking for free — ``masked_w`` collapses an inactive row's
support to ``{self}``, so the client keeps its own value exactly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core import sparse_topology as sparse_lib


def _cast(tree, dtype):
    if dtype is None:
        return tree
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def mix_dense(tree: Any, w, gossip_dtype=None) -> Any:
    """tree leaves: (n, ...) -> W @ leaves."""
    w = jnp.asarray(w, jnp.float32)

    def one(x):
        orig = x.dtype
        xc = x.astype(gossip_dtype) if gossip_dtype is not None else x
        # einsum in the gossip dtype (keeps the all-gathered operand narrow),
        # accumulate in f32.
        mixed = jnp.einsum(
            "ij,j...->i...", w.astype(xc.dtype), xc,
            preferred_element_type=jnp.float32,
        )
        return mixed.astype(orig)

    return jax.tree.map(one, tree)


def mix_ring(tree: Any, w_self: float, w_nbr: float, gossip_dtype=None) -> Any:
    """Ring mixing: w_self * x_i + w_nbr * (x_{i-1} + x_{i+1}).

    jnp.roll along the clients-sharded dim lowers to collective-permute.
    """

    def one(x):
        orig = x.dtype
        xc = x.astype(gossip_dtype) if gossip_dtype is not None else x
        n = x.shape[0]
        if n == 1:
            return x
        if n == 2:
            # single neighbor: w_nbr is already the full off-diagonal weight
            nbr = jnp.roll(xc, 1, axis=0)
            mixed = w_self * xc.astype(jnp.float32) + w_nbr * nbr.astype(jnp.float32)
        else:
            up = jnp.roll(xc, 1, axis=0)
            dn = jnp.roll(xc, -1, axis=0)
            mixed = (
                w_self * xc.astype(jnp.float32)
                + w_nbr * (up.astype(jnp.float32) + dn.astype(jnp.float32))
            )
        return mixed.astype(orig)

    return jax.tree.map(one, tree)


def mix_packed(tree: Any, w, gossip_dtype=None) -> Any:
    """One gossip for the whole pytree: ravel to (n, D), mix, unravel.

    Same math as ``mix_dense`` per leaf, but a single contraction over the
    packed buffer — one collective for the entire state instead of one per
    leaf.  The round-step path goes further (repro.kernels.ops
    ``fused_gossip_round`` fuses the correction/mixing epilogue too); this
    tree-level form serves generic callers.
    """
    spec = packing.pack_spec(tree)
    mixed = mix_dense(packing.pack(tree, spec), w, gossip_dtype=gossip_dtype)
    return packing.unpack(mixed, spec)


def mix_sparse(tree: Any, sp, gossip_dtype=None) -> Any:
    """One neighbor-gather gossip for the whole pytree: ravel to (n, D),
    ``sparse_topology.sparse_mix`` against the padded-CSR neighbor lists,
    unravel.  Same math as ``mix_packed`` at O(n·max_deg·D) instead of
    O(n²·D) — W never exists as an (n, n) array."""
    spec = packing.pack_spec(tree)
    mixed = sparse_lib.sparse_mix(sp, packing.pack(tree, spec),
                                  gossip_dtype=gossip_dtype)
    return packing.unpack(mixed, spec)


# ---------------------------------------------------------------------------
# robust (Byzantine-tolerant) aggregation
# ---------------------------------------------------------------------------

ROBUST_RULES = ("coord_median", "trimmed_mean")
# first-class mixing_impl names: dense form + sparse neighbor-gather form
ROBUST_IMPLS = ("coord_median", "trimmed_mean",
                "sparse_coord_median", "sparse_trimmed_mean")


def robust_rule(impl: str) -> str:
    """The aggregation rule of a robust mixing_impl name."""
    rule = impl[len("sparse_"):] if impl.startswith("sparse_") else impl
    if rule not in ROBUST_RULES:
        raise ValueError(f"not a robust mixing_impl: {impl!r} ({ROBUST_IMPLS})")
    return rule


def _robust_reduce(vals, valid, rule: str, trim: int) -> jnp.ndarray:
    """Per-coordinate order statistic over the valid slots of each row.

    vals: (n, m, D) candidate values per client; valid: (n, m) bool —
    invalid slots (padding, masked links, absent edges) are ignored, and so
    are non-finite values per coordinate: a client whose state has blown up
    (a diverged Byzantine attacker) must not occupy a trim slot forever —
    that would turn the symmetric b-trim into a permanently asymmetric trim
    of the honest values, a systematic bias.  Every row should keep ≥ 1
    finite valid slot per coordinate (the aggregating client itself).

    * ``coord_median`` — midpoint of the two middle order statistics of the
      k valid values (the even/odd-agnostic median).
    * ``trimmed_mean`` — mean after dropping the b smallest and b largest
      values per coordinate, b = min(trim, (k−1)//2) so at least one value
      always survives (the trim adapts to masked-down neighbor sets).

    k (hence b) is per-(row, coordinate): finiteness varies by coordinate.
    """
    if rule not in ROBUST_RULES:
        raise ValueError(f"unknown robust rule {rule!r}: {ROBUST_RULES}")
    vals = vals.astype(jnp.float32)
    n, m, d = vals.shape
    ok = valid[:, :, None] & jnp.isfinite(vals)              # (n, m, D)
    k = ok.sum(1).astype(jnp.int32)                          # (n, D) ≥ 1
    filled = jnp.where(ok, vals, jnp.inf)
    srt = jnp.sort(filled, axis=1)       # valid ascending, padding (inf) last
    if rule == "coord_median":
        lo = jnp.take_along_axis(srt, ((k - 1) // 2)[:, None, :], axis=1)
        hi = jnp.take_along_axis(srt, (k // 2)[:, None, :], axis=1)
        return (0.5 * (lo + hi))[:, 0, :]
    b = jnp.minimum(jnp.int32(trim), (k - 1) // 2)           # (n, D)
    rank = jnp.arange(m, dtype=jnp.int32)[None, :, None]
    keep = (rank >= b[:, None, :]) & (rank < (k - b)[:, None, :])
    # where-then-sum (not multiply) so the inf padding never meets a 0
    total = jnp.sum(jnp.where(keep, srt, 0.0), axis=1)
    return total / (k - 2 * b).astype(jnp.float32)


def robust_mix_dense(buf, w, *, rule: str, trim: int = 1,
                     gossip_dtype=None) -> jnp.ndarray:
    """Robust aggregation of a packed (n, D) buffer over the support of a
    dense (n, n) W: client i reduces over ``{j : w_ij > 0} ∪ {i}``.

    Mirrors ``mix_dense``'s dtype rules: the communicated values narrow to
    ``gossip_dtype``, the reduction itself runs in f32.
    """
    out_dtype = buf.dtype
    w = jnp.asarray(w, jnp.float32)
    n = w.shape[0]
    bg = (buf.astype(gossip_dtype) if gossip_dtype is not None
          else buf).astype(jnp.float32)
    valid = (w > 0.0) | jnp.eye(n, dtype=bool)
    vals = jnp.broadcast_to(bg[None, :, :], (n, n, bg.shape[1]))
    return _robust_reduce(vals, valid, rule, trim).astype(out_dtype)


def robust_mix_sparse(buf, sp, *, rule: str, trim: int = 1,
                      gossip_dtype=None) -> jnp.ndarray:
    """Neighbor-gather form of :func:`robust_mix_dense`: the candidate set
    is gathered through the padded-CSR neighbor lists — O(n·max_deg·D), no
    (n, n) array.  Validity comes from ``neighbor_w > 0``, so padding slots
    and masked links (``sparse_masked_w``) drop out and the self slot is
    always in; on ``densify``-equal supports this matches the dense form.
    """
    out_dtype = buf.dtype
    bg = (buf.astype(gossip_dtype) if gossip_dtype is not None
          else buf).astype(jnp.float32)
    n = sp.neighbor_idx.shape[0]
    gathered = jnp.take(bg, sp.neighbor_idx, axis=0)         # (n, max_deg, D)
    vals = jnp.concatenate([bg[:, None, :], gathered], axis=1)
    valid = jnp.concatenate(
        [jnp.ones((n, 1), bool), sp.neighbor_w > 0.0], axis=1)
    return _robust_reduce(vals, valid, rule, trim).astype(out_dtype)


def robust_mix_packed(tree: Any, w, *, rule: str, trim: int = 1,
                      gossip_dtype=None) -> Any:
    """Tree-level robust aggregation: ravel to (n, D), reduce, unravel.
    ``w`` dispatches the form — a ``SparseTopology`` takes the neighbor-
    gather path, anything array-like the dense one."""
    spec = packing.pack_spec(tree)
    red = (robust_mix_sparse if isinstance(w, sparse_lib.SparseTopology)
           else robust_mix_dense)
    mixed = red(packing.pack(tree, spec), w, rule=rule, trim=trim,
                gossip_dtype=gossip_dtype)
    return packing.unpack(mixed, spec)


MIXING_IMPLS = ("dense", "ring", "fused_dense", "fused_ring", "pallas_packed",
                "sparse_packed", "fused_round") + ROBUST_IMPLS


def make_mixer(topology: str, impl: str, w: np.ndarray,
               gossip_dtype: str = "float32", *, trim: int = 1):
    """Returns mix(tree) -> tree for the configured implementation."""
    if impl not in MIXING_IMPLS:
        raise ValueError(f"unknown mixing_impl {impl!r}: {MIXING_IMPLS}")
    gd = None if gossip_dtype in (None, "float32") else jnp.dtype(gossip_dtype)
    if impl in ROBUST_IMPLS:
        rule = robust_rule(impl)
        if impl.startswith("sparse_"):
            w = (w if isinstance(w, sparse_lib.SparseTopology)
                 else sparse_lib.from_dense(np.asarray(w)))
        return lambda tree: robust_mix_packed(tree, w, rule=rule, trim=trim,
                                              gossip_dtype=gd)
    if impl.endswith("ring"):
        if topology != "ring":
            raise ValueError(
                f"mixing_impl={impl!r} is a neighbor-only exchange, valid "
                f"only for topology='ring' (got {topology!r}); use 'dense', "
                f"'fused_dense', or 'pallas_packed' for arbitrary W")
        n = w.shape[0]
        w_self = float(w[0, 0])
        w_nbr = float(w[0, 1 % n]) if n > 1 else 0.0
        return lambda tree: mix_ring(tree, w_self, w_nbr, gossip_dtype=gd)
    if impl == "sparse_packed":
        sp = (w if isinstance(w, sparse_lib.SparseTopology)
              else sparse_lib.from_dense(np.asarray(w)))
        return lambda tree: mix_sparse(tree, sp, gossip_dtype=gd)
    if impl == "pallas_packed":
        return lambda tree: mix_packed(tree, w, gossip_dtype=gd)
    if impl == "fused_round":
        # whole-round lowering: there is no standalone mix step — the local
        # steps, gossip, and correction all live inside one kernel call,
        # routed by kgt_minimax.make_round_step.  Falling through to
        # mix_dense here would silently run the wrong program.
        raise ValueError(
            "mixing_impl='fused_round' has no standalone mixer; it is "
            "routed whole-round by kgt_minimax.make_round_step")
    return lambda tree: mix_dense(tree, w, gossip_dtype=gd)


def make_traced_mixer(impl: str, gossip_dtype: str = "float32", *,
                      trim: int = 1):
    """Traced-W analogue of :func:`make_mixer`: returns ``mix(tree, w)``
    where W is an operand of the surrounding jit — a per-round *sampled*
    matrix (``repro.core.stochastic_topology``) or a participation-masked
    one — instead of a constant baked into the program.

    The neighbor-only ring impls hard-code the exchange pattern and cannot
    realize an arbitrary per-round W, so they raise; ``dense``/``fused_dense``
    lower to the dense einsum and ``pallas_packed`` to the packed tree
    contraction, both of which already take W as a runtime value.
    """
    if impl not in MIXING_IMPLS:
        raise ValueError(f"unknown mixing_impl {impl!r}: {MIXING_IMPLS}")
    if impl.endswith("ring"):
        raise ValueError(
            f"mixing_impl={impl!r} is a neighbor-only exchange and cannot "
            "realize a traced (per-round random or participation-masked) W; "
            "use 'dense', 'fused_dense', or 'pallas_packed'")
    gd = None if gossip_dtype in (None, "float32") else jnp.dtype(gossip_dtype)
    if impl in ROBUST_IMPLS:
        # the traced operand is W-as-support: a SparseTopology pytree for
        # the sparse_* forms, an (n, n) array otherwise — robust_mix_packed
        # dispatches on it
        rule = robust_rule(impl)
        return lambda tree, w: robust_mix_packed(tree, w, rule=rule,
                                                 trim=trim, gossip_dtype=gd)
    if impl == "sparse_packed":
        # here the traced operand is a SparseTopology pytree, not an array
        return lambda tree, sp: mix_sparse(tree, sp, gossip_dtype=gd)
    if impl == "pallas_packed":
        return lambda tree, w: mix_packed(tree, w, gossip_dtype=gd)
    if impl == "fused_round":
        raise ValueError(
            "mixing_impl='fused_round' has no standalone mixer; it is "
            "routed whole-round by kgt_minimax.make_round_step")
    return lambda tree, w: mix_dense(tree, w, gossip_dtype=gd)


def consensus_error(tree: Any) -> jnp.ndarray:
    """(1/n) Σ_i ||T_i - mean_j T_j||² summed over leaves (client variance Ξ)."""
    def one(x):
        m = x.mean(0, keepdims=True)
        return jnp.sum(jnp.square((x - m).astype(jnp.float32))) / x.shape[0]
    return sum(jax.tree.leaves(jax.tree.map(one, tree)))
