"""Concrete NC-SC minimax objectives.

* ``quadratic_problem`` — synthetic NC-SC with closed-form Φ and ∇Φ; the
  workhorse for validating the paper's theory (V1–V6 in DESIGN.md).
* ``dro_problem`` — distributionally-robust LM training over G token groups;
  y ∈ R^G, f_i(x,y) = Σ_g y_g ℓ_g(x; D_i) − μ/2‖y‖²  (linear in y ⇒ μ-SC).
* ``adversarial_problem`` — universal adversarial embedding perturbation;
  y ∈ R^{d_model}, f_i(x,y) = ℓ(x; E+y) − μ/2‖y‖².
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.minimax import MinimaxProblem


# ---------------------------------------------------------------------------
# Synthetic quadratic NC-SC (exact oracles)
# ---------------------------------------------------------------------------

def make_quadratic_data(
    key,
    n_clients: int,
    dx: int = 10,
    dy: int = 5,
    mu: float = 1.0,
    l_smooth: float = 4.0,
    heterogeneity: float = 1.0,
    nonconvexity: float = 0.5,
):
    """Per-client data for  f_i(x,y) = ½xᵀA_i x + q_iᵀx + yᵀB_i x + b_iᵀy − μ/2‖y‖².

    A_i symmetric with eigenvalues in [-nonconvexity·L, L·scale] (nonconvex),
    B_i, b_i, q_i heterogeneous with scale ``heterogeneity`` around a shared
    mean.  Returns dict of stacked (n, ...) arrays.
    """
    ks = jax.random.split(key, 6)
    # Global Ā must keep Φ bounded below (Assumption 1): draw it PSD with
    # eigenvalues in [0.1, l_smooth/2].  Per-client nonconvexity/heterogeneity
    # enters through ZERO-MEAN symmetric perturbations E_i (Σ_i E_i = 0), so
    # each f_i is nonconvex in x while the global primal stays bounded.
    q_rot = jnp.linalg.qr(jax.random.normal(ks[0], (dx, dx)))[0]
    eigs = jnp.linspace(0.1, l_smooth / 2, dx)
    base_a = (q_rot * eigs) @ q_rot.T

    e = jax.random.normal(ks[1], (n_clients, dx, dx)) / np.sqrt(dx)
    e = 0.5 * (e + jnp.swapaxes(e, -1, -2))
    e = e - e.mean(0, keepdims=True)  # exactly zero-mean across clients
    a = base_a[None] + (nonconvexity + heterogeneity) * e

    base_b = jax.random.normal(ks[2], (dy, dx)) / np.sqrt(max(dx, dy))
    base_b = base_b * (l_smooth / 2 / jnp.linalg.norm(base_b, 2))
    db = jax.random.normal(ks[3], (n_clients, dy, dx)) / np.sqrt(dx)
    db = db - db.mean(0, keepdims=True)
    b_mat = base_b[None] + heterogeneity * db

    b_vec = jax.random.normal(ks[4], (n_clients, dy)) * heterogeneity
    q_vec = jax.random.normal(ks[5], (n_clients, dx)) * heterogeneity
    return {"A": a, "B": b_mat, "b": b_vec, "q": q_vec, "mu": jnp.float32(mu)}


def quadratic_problem(data: Dict[str, Any], sigma: float = 0.0) -> MinimaxProblem:
    """MinimaxProblem over per-client slices of ``data``.

    The per-client batch is {"A": (dx,dx), "B": (dy,dx), "b": (dy,), "q": (dx,)}
    (one slice).  Stochasticity: additive Gaussian noise of scale sigma on the
    value's linear terms (⇒ unbiased, bounded-variance gradients, Assumption 3).
    """
    mu = float(data["mu"])
    dx = data["A"].shape[-1]
    dy = data["B"].shape[-2]

    a_bar = np.asarray(data["A"].mean(0))
    b_bar = np.asarray(data["B"].mean(0))
    bv_bar = np.asarray(data["b"].mean(0))
    q_bar = np.asarray(data["q"].mean(0))

    def value(x, y, batch, key):
        f = (
            0.5 * x @ (batch["A"] @ x)
            + batch["q"] @ x
            + y @ (batch["B"] @ x)
            + batch["b"] @ y
            - 0.5 * mu * jnp.sum(y * y)
        )
        if sigma > 0.0:
            kx, ky = jax.random.split(key)
            f = f + sigma * (
                jax.random.normal(kx, (dx,)) @ x + jax.random.normal(ky, (dy,)) @ y
            )
        return f

    def phi_grad(x):
        # y*(x) = (B̄x + b̄)/μ ; ∇Φ = Āx + q̄ + B̄ᵀ y*(x)
        ystar = (b_bar @ x + bv_bar) / mu
        return a_bar @ x + q_bar + b_bar.T @ ystar

    def full_grads(x, y):
        gx = a_bar @ x + q_bar + b_bar.T @ y
        gy = b_bar @ x + bv_bar - mu * y
        return gx, gy

    def affine_coeffs(batch, key):
        return _quadratic_affine_coeffs(
            batch, key, mu=mu, dx=dx, dy=dy,
            sigma=(jnp.float32(sigma) if sigma > 0.0 else None))

    return MinimaxProblem(
        init_x=lambda key: jax.random.normal(key, (dx,)),
        init_y=lambda key: jnp.zeros((dy,)),
        value=value,
        phi_grad=phi_grad,
        full_grads=full_grads,
        affine_coeffs=affine_coeffs,
        mu=mu,
    )


def _quadratic_affine_coeffs(batch, key, *, mu, dx, dy, sigma):
    """(G, h) with (∇x f, ∇y f) = split(G z + h) for z = concat(x, y).

        G = [[A, Bᵀ], [B, −μI]]       h = [q; b] (+ σ·noise)

    The noise term reuses the exact key split of ``value`` (kx for x-terms,
    ky for y-terms), so the fused-round path sees the *same* stochastic
    gradients as autodiff through ``value`` — bit-level parity modulo matmul
    reassociation, held to 1e-6 by tests/test_fused_round.py.
    """
    a, b_mat = batch["A"], batch["B"]
    top = jnp.concatenate([a, jnp.swapaxes(b_mat, -1, -2)], axis=-1)
    bottom = jnp.concatenate(
        [b_mat, -jnp.float32(mu) * jnp.eye(dy, dtype=a.dtype)], axis=-1)
    g = jnp.concatenate([top, bottom], axis=-2)
    h = jnp.concatenate([batch["q"], batch["b"]], axis=-1)
    if sigma is not None:
        kx, ky = jax.random.split(key)
        h = h + sigma * jnp.concatenate(
            [jax.random.normal(kx, (dx,)), jax.random.normal(ky, (dy,))])
    return g, h


def quadratic_cell_problem(dx: int, dy: int, mu: float = 1.0,
                           noise: bool = False) -> MinimaxProblem:
    """The quadratic with *all* per-client coefficients read from the batch.

    ``quadratic_problem`` closes over one client-stacked ``data`` dict and a
    static noise scale — one traced program per (data, sigma) point.  A sweep
    cell (``repro.sweep``) instead vmaps a single program over a trajectory
    axis where the data (heterogeneity, seed) and sigma are just array
    leaves, so here they arrive through ``batch``: the per-client slice is
    ``{"A", "B", "b", "q"}`` plus, when ``noise``, a scalar ``"sigma"``.

    The value expression is term-for-term the one in ``quadratic_problem``
    (that is what makes a batched trajectory bit-identical to the same point
    run through the static path).  Whether noise ops exist in the graph is a
    *static* program property — a cell mixing sigma=0 with sigma>0 must be
    split by the grid layer, not multiplied by a traced zero.

    No Φ oracle: the exact ``phi_grad`` needs the client-*mean* coefficients,
    which the sweep runner evaluates itself over its stacked constants.
    """

    def value(x, y, batch, key):
        f = (
            0.5 * x @ (batch["A"] @ x)
            + batch["q"] @ x
            + y @ (batch["B"] @ x)
            + batch["b"] @ y
            - 0.5 * mu * jnp.sum(y * y)
        )
        if noise:
            kx, ky = jax.random.split(key)
            f = f + batch["sigma"] * (
                jax.random.normal(kx, (dx,)) @ x + jax.random.normal(ky, (dy,)) @ y
            )
        return f

    def affine_coeffs(batch, key):
        return _quadratic_affine_coeffs(
            batch, key, mu=mu, dx=dx, dy=dy,
            sigma=(batch["sigma"] if noise else None))

    return MinimaxProblem(
        init_x=lambda key: jax.random.normal(key, (dx,)),
        init_y=lambda key: jnp.zeros((dy,)),
        value=value,
        affine_coeffs=affine_coeffs,
        mu=mu,
    )


# ---------------------------------------------------------------------------
# DRO over a language model
# ---------------------------------------------------------------------------

def dro_problem(cfg: ModelConfig, *, num_groups: int = 8, mu: float = 1.0,
                compute_dtype=jnp.bfloat16, remat: bool = False) -> MinimaxProblem:
    from repro.models import model as model_lib

    def init_x(key):
        return model_lib.init_params(cfg, key)

    def init_y(key):
        return jnp.zeros((num_groups,))

    def value(x, y, batch, key):
        del key  # stochasticity comes from the data batch itself
        losses, aux = model_lib.per_group_loss(
            x, batch, cfg, num_groups=num_groups,
            compute_dtype=compute_dtype, remat=remat)
        return jnp.dot(y, losses) + aux - 0.5 * mu * jnp.sum(y * y)

    return MinimaxProblem(init_x=init_x, init_y=init_y, value=value, mu=mu)


# ---------------------------------------------------------------------------
# Adversarial embedding perturbation
# ---------------------------------------------------------------------------

def adversarial_problem(cfg: ModelConfig, *, mu: float = 10.0, scale: float = 0.1,
                        compute_dtype=jnp.bfloat16,
                        remat: bool = False) -> MinimaxProblem:
    from repro.models import model as model_lib

    def init_x(key):
        return model_lib.init_params(cfg, key)

    def init_y(key):
        return jnp.zeros((cfg.d_model,))

    def value(x, y, batch, key):
        del key
        perturbed = dict(batch)
        perturbed["embed_bias"] = scale * y
        logits, _, aux = model_lib.forward(
            x, perturbed, cfg, mode="train", compute_dtype=compute_dtype,
            remat=remat)
        nll = model_lib.token_losses(logits, batch["labels"]).mean()
        return nll + aux - 0.5 * mu * jnp.sum(y * y)

    return MinimaxProblem(init_x=init_x, init_y=init_y, value=value, mu=mu)
