"""Ravel a client-stacked pytree into one contiguous ``(n, D)`` buffer.

Every K-GT-Minimax state variable is a pytree whose leaves carry a leading
clients dim ``n`` (``x: (n, …)``, corrections likewise).  The round epilogue
(gossip + correction + parameter mixing) is linear over clients, so instead
of issuing one gossip per leaf it can operate on a single packed ``(n, D)``
f32 buffer: each leaf is reshaped to ``(n, -1)`` and concatenated along the
feature axis at a fixed per-leaf offset.  ``PackSpec`` remembers the layout
(treedef, per-leaf trailing shape, dtype, offset) so ``unpack`` restores the
original structure bit-for-bit in shape and dtype.

Packing is pure jnp (traceable under jit); under GSPMD the buffer keeps the
leading dim on the ``clients`` mesh axis, so a single collective moves the
whole state where the per-leaf path launched one per leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PACK_DTYPE = jnp.float32


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Layout of a packed buffer: where each leaf lives and what it was."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]   # per-leaf trailing shape (no n)
    dtypes: Tuple[Any, ...]               # per-leaf original dtype
    offsets: Tuple[int, ...]              # per-leaf start column
    sizes: Tuple[int, ...]                # per-leaf column count
    n: int                                # leading clients dim
    dim: int                              # total packed width D


def pack_spec(tree: Any) -> PackSpec:
    """Layout for ``tree`` (concrete arrays or ShapeDtypeStructs)."""
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("cannot pack an empty pytree")
    n = leaves[0].shape[0]
    shapes, dtypes, offsets, sizes = [], [], [], []
    off = 0
    for leaf in leaves:
        if leaf.ndim < 1 or leaf.shape[0] != n:
            raise ValueError(
                f"every leaf needs the same leading clients dim {n}, "
                f"got shape {leaf.shape}")
        size = 1
        for s in leaf.shape[1:]:
            size *= s
        shapes.append(tuple(leaf.shape[1:]))
        dtypes.append(jnp.dtype(leaf.dtype))
        offsets.append(off)
        sizes.append(size)
        off += size
    return PackSpec(treedef=treedef, shapes=tuple(shapes), dtypes=tuple(dtypes),
                    offsets=tuple(offsets), sizes=tuple(sizes), n=n, dim=off)


def pack(tree: Any, spec: PackSpec | None = None) -> jnp.ndarray:
    """Ravel ``tree`` into an ``(n, D)`` f32 buffer (leaf order = tree order)."""
    spec = spec or pack_spec(tree)
    leaves = jax.tree.leaves(tree)
    cols = [leaf.reshape(spec.n, -1).astype(PACK_DTYPE) for leaf in leaves]
    return cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)


def unpack(buf: jnp.ndarray, spec: PackSpec) -> Any:
    """Inverse of ``pack``: restore leaf shapes and original dtypes."""
    if buf.shape != (spec.n, spec.dim):
        raise ValueError(f"buffer {buf.shape} does not match spec "
                         f"({spec.n}, {spec.dim})")
    leaves = [
        buf[:, off:off + size].reshape(spec.n, *shape).astype(dtype)
        for off, size, shape, dtype
        in zip(spec.offsets, spec.sizes, spec.shapes, spec.dtypes)
    ]
    return jax.tree.unflatten(spec.treedef, leaves)
