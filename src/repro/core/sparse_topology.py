"""Sparse communication topologies: padded-CSR neighbor lists, never (n, n).

Every dense gossip path — ``mixing.mix_dense``, the packed Pallas kernel,
``stochastic_topology``'s samplers — materializes the full (n, n) mixing
matrix, so per-round memory and compute are O(n²) and the clients axis caps
out at toy sizes.  The K-GT-Minimax analysis (Assumption 4) only needs a
symmetric doubly stochastic W *supported on the communication graph*; for
the ring/torus/exp graphs the paper sweeps, that support is O(n) or
O(n log n) edges.  This module is the edge-proportional representation:

:class:`SparseTopology` — per-client neighbor lists in padded CSR form:

* ``neighbor_idx (n, max_deg) int32`` — neighbor ids, ascending per row;
  padding slots repeat the client's own index;
* ``neighbor_w (n, max_deg) f32`` — the off-diagonal weights w_ij; padding
  slots carry weight 0.0, so every consumer can reduce over all slots;
* ``self_w (n,) f32`` — the diagonal w_ii;
* ``degree (n,) int32`` — valid slots per row (``offsets`` derives the
  flattened-CSR segment offsets).

It is a registered pytree, so a *sampled* per-round topology flows as a
traced operand through jit/scan/vmap exactly like the dense W did on the
churn path — at O(n·max_deg) instead of O(n²).

Constructors mirror ``repro.core.topology`` (``sparse_ring`` /
``sparse_torus`` / ``sparse_exp`` / ``sparse_full`` / ``sparse_star`` via
Metropolis–Hastings weights, which coincide with the dense constructors'
weights on all of these graphs), plus :func:`sparse_hierarchical` — a
cluster-of-clusters graph (dense intra-cluster, ring over cluster leaders)
for the federated "silos of devices" regime.  :func:`from_dense` /
:func:`densify` bridge to the dense world bit-exactly (round-trip tested).

Sampling (the sparse analogue of ``repro.core.stochastic_topology``) emits
**edge lists, never an (n, n) array**: :func:`make_sparse_w_sampler` draws
per-round Erdős–Rényi percolation of the support graph, randomized pairwise
gossip on a support edge, or per-client dropout — each on the same
``round_stream_key``/W_STREAM fold_in discipline as the dense samplers, so
checkpoint restore regenerates the identical sequence.  Every draw is
symmetric doubly stochastic by construction, so the Σ_i c_i = 0 and
mean-dynamics invariants carry over at any scale.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stochastic_topology as stoch_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SparseTopology:
    """Padded-CSR neighbor-list mixing matrix (see module docstring)."""
    neighbor_idx: jnp.ndarray   # (n, max_deg) int32, padding = own index
    neighbor_w: jnp.ndarray     # (n, max_deg) f32,   padding = 0.0
    self_w: jnp.ndarray         # (n,) f32 diagonal
    degree: jnp.ndarray         # (n,) int32 valid slots per row

    @property
    def n(self) -> int:
        return self.neighbor_idx.shape[0]

    @property
    def max_degree(self) -> int:
        return self.neighbor_idx.shape[1]

    @property
    def offsets(self) -> jnp.ndarray:
        """(n+1,) segment offsets of the flattened (ragged) CSR view."""
        return jnp.concatenate([
            jnp.zeros((1,), jnp.int32),
            jnp.cumsum(self.degree.astype(jnp.int32))])

    @property
    def num_edges(self) -> int:
        """Directed edge count Σ_i deg_i (host; needs a concrete degree)."""
        return int(np.sum(np.asarray(self.degree)))


# ---------------------------------------------------------------------------
# dense bridge
# ---------------------------------------------------------------------------

def from_dense(w, tol: float = 0.0) -> SparseTopology:
    """Extract the neighbor lists of a dense (n, n) mixing matrix.

    Off-diagonal entries with ``|w_ij| > tol`` become neighbor slots in
    ascending column order; the diagonal becomes ``self_w``.  Weights are
    stored f32, so ``densify(from_dense(w))`` equals ``w.astype(f32)``
    bit-for-bit.  This is the O(n²) bridge for matrices that already exist —
    use the direct ``sparse_*`` constructors to *build* at scale.
    """
    w = np.asarray(w)
    n = w.shape[0]
    if w.shape != (n, n):
        raise ValueError(f"from_dense needs a square matrix, got {w.shape}")
    cols_per = []
    deg = np.zeros(n, np.int32)
    for i in range(n):
        cols = [j for j in range(n) if j != i and abs(w[i, j]) > tol]
        cols_per.append(cols)
        deg[i] = len(cols)
    max_deg = max(1, int(deg.max()) if n else 1)
    nidx = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, max_deg))
    nw = np.zeros((n, max_deg), np.float32)
    for i, cols in enumerate(cols_per):
        if cols:
            nidx[i, : len(cols)] = np.asarray(cols, np.int32)
            nw[i, : len(cols)] = w[i, cols].astype(np.float32)
    return SparseTopology(
        neighbor_idx=jnp.asarray(nidx), neighbor_w=jnp.asarray(nw),
        self_w=jnp.asarray(np.diag(w).astype(np.float32)),
        degree=jnp.asarray(deg))


def densify(sp: SparseTopology) -> jnp.ndarray:
    """(n, n) f32 mixing matrix of ``sp`` (traceable).

    Padding slots scatter-add exact 0.0 onto the diagonal, so the round
    trip ``densify(from_dense(w))`` reproduces ``w.astype(f32)`` bit-exactly.
    """
    n = sp.n
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], sp.neighbor_idx.shape)
    w = jnp.zeros((n, n), jnp.float32)
    w = w.at[rows, sp.neighbor_idx].add(sp.neighbor_w.astype(jnp.float32))
    return w.at[jnp.arange(n), jnp.arange(n)].add(sp.self_w.astype(jnp.float32))


# ---------------------------------------------------------------------------
# direct constructors (O(edges), host-side)
# ---------------------------------------------------------------------------

def _from_adjacency(adj) -> SparseTopology:
    """Metropolis–Hastings weights on symmetric adjacency lists:
    w_ij = 1/(1 + max(d_i, d_j)), each diagonal takes its row's leftover.

    On ring/torus/exp/full/star this reproduces the dense constructors'
    weights (for the uniform-degree hand-weighted graphs MH degenerates to
    the same 1/3, 1/5, 1/n values).
    """
    n = len(adj)
    deg = np.array([len(a) for a in adj], np.int32)
    max_deg = max(1, int(deg.max()) if n else 1)
    nidx = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, max_deg))
    nw = np.zeros((n, max_deg), np.float32)
    sw = np.zeros((n,), np.float32)
    for i in range(n):
        nbrs = sorted(adj[i])
        if nbrs:
            row = np.array([1.0 / (1 + max(int(deg[i]), int(deg[j])))
                            for j in nbrs], np.float64)
            nidx[i, : len(nbrs)] = np.asarray(nbrs, np.int32)
            nw[i, : len(nbrs)] = row.astype(np.float32)
            sw[i] = np.float32(1.0 - row.sum())
        else:
            sw[i] = np.float32(1.0)
    return SparseTopology(
        neighbor_idx=jnp.asarray(nidx), neighbor_w=jnp.asarray(nw),
        self_w=jnp.asarray(sw), degree=jnp.asarray(deg))


def sparse_ring(n: int) -> SparseTopology:
    adj = [set() for _ in range(n)]
    if n > 1:
        for i in range(n):
            adj[i].update({(i + 1) % n, (i - 1) % n})
    return _from_adjacency(adj)


def sparse_torus(n: int) -> SparseTopology:
    s = int(round(np.sqrt(n)))
    if s * s != n:
        raise ValueError(f"torus needs a square n, got {n}")
    if s <= 2:
        return sparse_ring(n)
    adj = [set() for _ in range(n)]
    for r in range(s):
        for c in range(s):
            i = r * s + c
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                adj[i].add(((r + dr) % s) * s + (c + dc) % s)
    return _from_adjacency(adj)


def sparse_exp(n: int) -> SparseTopology:
    """Exponential graph (i ↔ i ± 2^k): degree O(log n), the scaling
    workhorse — spectral gap independent of n at ~2 log₂ n edges/client."""
    adj = [set() for _ in range(n)]
    k = 1
    while k < n:
        for i in range(n):
            adj[i].update({(i + k) % n, (i - k) % n})
        k *= 2
    for i in range(n):
        adj[i].discard(i)
    return _from_adjacency(adj)


def sparse_full(n: int) -> SparseTopology:
    stoch_lib.check_dense_materialization(n, "sparse_full (complete graph)")
    adj = [set(range(n)) - {i} for i in range(n)]
    return _from_adjacency(adj)


def sparse_star(n: int) -> SparseTopology:
    stoch_lib.check_dense_materialization(n, "sparse_star (hub degree n-1)")
    adj = [set() for _ in range(n)]
    for i in range(1, n):
        adj[0].add(i)
        adj[i].add(0)
    return _from_adjacency(adj)


def sparse_hierarchical(n: int, cluster_size: int) -> SparseTopology:
    """Cluster-of-clusters graph: each cluster of ``cluster_size`` clients is
    fully connected internally; cluster leaders (the first member) form a
    ring across clusters.  Max degree is cluster_size + 1 regardless of n —
    the federated "silos of devices" topology.  MH weights keep it symmetric
    doubly stochastic despite the leader/member degree asymmetry."""
    if cluster_size < 1 or n % cluster_size != 0:
        raise ValueError(
            f"cluster_size must divide n, got n={n}, cluster_size={cluster_size}")
    q = n // cluster_size
    adj = [set() for _ in range(n)]
    for g in range(q):
        base = g * cluster_size
        for a in range(base, base + cluster_size):
            for b in range(base, base + cluster_size):
                if a != b:
                    adj[a].add(b)
    if q == 2:
        adj[0].add(cluster_size)
        adj[cluster_size].add(0)
    elif q > 2:
        for g in range(q):
            lead, nxt = g * cluster_size, ((g + 1) % q) * cluster_size
            adj[lead].add(nxt)
            adj[nxt].add(lead)
    return _from_adjacency(adj)


SPARSE_TOPOLOGIES = {
    "ring": sparse_ring,
    "torus": sparse_torus,
    "exp": sparse_exp,
    "full": sparse_full,
    "star": sparse_star,
}


def sparse_mixing_matrix(name: str, n: int) -> SparseTopology:
    """Sparse counterpart of ``topology.mixing_matrix(name, n)``."""
    try:
        return SPARSE_TOPOLOGIES[name](n)
    except KeyError:
        raise KeyError(
            f"unknown topology {name!r}: {sorted(SPARSE_TOPOLOGIES)}") from None


# ---------------------------------------------------------------------------
# traceable per-round operators
# ---------------------------------------------------------------------------

def sparse_masked_w(sp: SparseTopology, mask) -> SparseTopology:
    """Self-loop fallback on the neighbor lists — the sparse analogue of
    ``stochastic_topology.masked_w``: w′_ij = w_ij·m_i·m_j on edges, each
    diagonal absorbs its row's lost mass.  Symmetric doubly stochastic for
    any 0/1 mask; a masked-out client's row collapses to e_i exactly
    (self_w = 1.0, all neighbor weights 0.0)."""
    m = mask.astype(jnp.float32)
    nw = (sp.neighbor_w.astype(jnp.float32)
          * m[:, None] * m[sp.neighbor_idx])
    return dataclasses.replace(
        sp, neighbor_w=nw, self_w=1.0 - nw.sum(1))


def sparse_mix(sp: SparseTopology, buf, gossip_dtype=None) -> jnp.ndarray:
    """``(W @ buf)`` for a packed (n, D) buffer by neighbor-row gather —
    O(n·max_deg·D) instead of the dense O(n²·D) contraction.  Mirrors
    ``mixing.mix_dense``'s dtype rules: operands (the communicated values
    and weights) narrow to ``gossip_dtype``, accumulation is f32."""
    out_dtype = buf.dtype
    bg = buf.astype(gossip_dtype) if gossip_dtype is not None else buf
    nwg = sp.neighbor_w.astype(bg.dtype)
    swg = sp.self_w.astype(bg.dtype)
    gathered = jnp.take(bg, sp.neighbor_idx, axis=0)      # (n, max_deg, D)
    mixed = (swg.astype(jnp.float32)[:, None] * bg.astype(jnp.float32)
             + jnp.einsum("nm,nmd->nd", nwg, gathered,
                          preferred_element_type=jnp.float32))
    return mixed.astype(out_dtype)


# ---------------------------------------------------------------------------
# per-round samplers (edge lists, never an (n, n) array)
# ---------------------------------------------------------------------------

def _pair_slots(nidx: np.ndarray, deg: np.ndarray) -> np.ndarray:
    """pair_slot[i, s] = the slot of i in neighbor j's list, where
    j = nidx[i, s] — the inverse map that lets a per-edge draw be read
    canonically from both endpoints.  Padding slots point at themselves."""
    n, m = nidx.shape
    ps = np.tile(np.arange(m, dtype=np.int32), (n, 1))
    slot_of = [
        {int(j): s for s, j in enumerate(nidx[i, : int(deg[i])])}
        for i in range(n)
    ]
    for i in range(n):
        for s in range(int(deg[i])):
            j = int(nidx[i, s])
            if i not in slot_of[j]:
                raise ValueError(
                    f"support graph is not symmetric: edge {i}->{j} has no "
                    f"reverse slot")
            ps[i, s] = slot_of[j][i]
    return ps


def make_sparse_w_sampler(
    family: str,
    support: SparseTopology,
    key,
    *,
    edge_prob=0.5,
    client_drop_prob=0.3,
) -> Callable[[jnp.ndarray], SparseTopology]:
    """``w_fn(round_idx) -> SparseTopology``: this round's sparse mixing
    matrix, drawn on the support graph — the edge-list analogue of
    ``stochastic_topology.make_w_sampler``.

    * ``static`` — the support itself every round;
    * ``erdos_renyi`` — each support edge kept independently with
      probability ``edge_prob`` (bond percolation of the support; one
      canonical uniform per undirected edge keeps the draw symmetric),
      Metropolis–Hastings weights on the realized degrees;
    * ``pairwise`` — randomized gossip on one uniformly random *support*
      edge (the dense family draws from all pairs; with a sparse support
      only graph edges can communicate);
    * ``dropout`` — per-client Bernoulli link dropout of the support
      weights with self-loop fallback (same draws as the dense family).

    Pure and jit-traceable in ``round_idx`` on the
    ``round_stream_key``/W_STREAM discipline; ``edge_prob`` /
    ``client_drop_prob`` may be traced scalars (sweep axes).  The support
    must be host-concrete (its structure is precomputed here once).
    """
    if family not in stoch_lib.TOPOLOGY_FAMILIES:
        raise ValueError(
            f"unknown topology family {family!r}: {stoch_lib.TOPOLOGY_FAMILIES}")
    if family == "static":
        return lambda round_idx: support

    nidx = np.asarray(support.neighbor_idx)
    deg = np.asarray(support.degree)
    n, m = nidx.shape
    if family == "dropout":
        def sample_dropout(r):
            keep = stoch_lib.bernoulli_mask(
                stoch_lib.round_stream_key(key, r, stoch_lib.W_STREAM),
                n, 1.0 - client_drop_prob)
            return sparse_masked_w(support, keep)

        return sample_dropout

    pair_slot = jnp.asarray(_pair_slots(nidx, deg))
    valid = jnp.asarray(nidx != np.arange(n, dtype=np.int32)[:, None])
    nidx_j = support.neighbor_idx

    if family == "erdos_renyi":
        own = jnp.arange(n, dtype=nidx_j.dtype)[:, None]

        def sample_er(r):
            u = jax.random.uniform(
                stoch_lib.round_stream_key(key, r, stoch_lib.W_STREAM), (n, m))
            # one canonical uniform per undirected edge: the draw "belongs"
            # to the lower-indexed endpoint; the higher endpoint gathers it
            # through the pair_slot inverse map, so keep is symmetric
            u_canon = jnp.where(nidx_j < own, u[nidx_j, pair_slot], u)
            keep = valid & (u_canon < edge_prob)
            d = keep.sum(1)
            denom = 1.0 + jnp.maximum(d[:, None], d[nidx_j]).astype(jnp.float32)
            nw = keep.astype(jnp.float32) / denom
            return SparseTopology(
                neighbor_idx=nidx_j, neighbor_w=nw,
                self_w=1.0 - nw.sum(1), degree=support.degree)

        return sample_er

    # pairwise: one uniformly random support edge averages, everyone holds.
    # Host-precompute the directed i<j edge list once; the per-round draw is
    # a single randint + two scatter writes.
    ei, es = np.nonzero((nidx > np.arange(n)[:, None])
                        & (np.arange(m)[None, :] < deg[:, None]))
    num_edges = len(ei)
    if num_edges == 0:
        identity = SparseTopology(
            neighbor_idx=nidx_j,
            neighbor_w=jnp.zeros((n, m), jnp.float32),
            self_w=jnp.ones((n,), jnp.float32), degree=support.degree)
        return lambda round_idx: identity
    edges_i = jnp.asarray(ei.astype(np.int32))
    edges_s = jnp.asarray(es.astype(np.int32))

    def sample_pairwise(r):
        t = jax.random.randint(
            stoch_lib.round_stream_key(key, r, stoch_lib.W_STREAM),
            (), 0, num_edges)
        i, s = edges_i[t], edges_s[t]
        j, s2 = nidx_j[i, s], pair_slot[i, s]
        nw = jnp.zeros((n, m), jnp.float32).at[i, s].set(0.5).at[j, s2].set(0.5)
        sw = jnp.ones((n,), jnp.float32).at[i].set(0.5).at[j].set(0.5)
        return SparseTopology(neighbor_idx=nidx_j, neighbor_w=nw,
                              self_w=sw, degree=support.degree)

    return sample_pairwise
