"""Time-varying random topologies and partial client participation.

The paper analyzes K-GT-Minimax on a *fixed* gossip matrix with every
client active every round; the decentralized-FL settings it targets are
defined by churn — links come and go, clients drop out.  This module opens
both axes as **on-device per-round samplers**: pure-jnp functions of the
round index that draw this round's mixing matrix W (and/or a participation
mask) *inside* the scanned chunk, on the same ``fold_in`` key discipline as
the data sampler (`repro.engine.sampler`), so a checkpoint restored at
round r regenerates the identical W/mask sequence bit-for-bit.

Topology families (:data:`TOPOLOGY_FAMILIES`):

* ``static`` — the configured ``cfg.topology`` matrix every round (the
  degenerate member, so churn-aware call sites need no special case);
* ``erdos_renyi`` — G(n, p): each undirected edge present independently
  with probability ``edge_prob``, Metropolis–Hastings weights on the drawn
  graph (:func:`metropolis_weights`, the traceable analogue of
  ``topology.metropolis``);
* ``pairwise`` — randomized gossip: one uniformly random pair averages,
  everyone else holds (W = I − ½(e_i−e_j)(e_i−e_j)ᵀ);
* ``dropout`` — per-client Bernoulli dropout of the configured base
  topology with self-loop fallback (:func:`masked_w`).

Every sampled W is symmetric doubly stochastic by construction — exactly
Assumption 4 minus the fixed spectral gap — so the two invariants the test
suite holds every round step to (client-mean dynamics independent of W,
Σ_i c_i = 0) carry over to arbitrary drawn sequences.

:func:`masked_w` is also the participation primitive: inactive clients'
rows/columns collapse to e_i (they neither send nor receive; the lost
off-diagonal mass folds into each *partner's* diagonal), which is what lets
``make_round_step(participation=True)`` freeze (θ, c) of inactive clients
while active clients keep mixing — and keeps W' doubly stochastic, hence
Σc = 0, under ANY mask.

``edge_prob`` / ``client_drop_prob`` / ``rate`` may be traced scalars —
``repro.sweep`` batches them as grid axes — while the family itself is a
static program property (a cell split).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

TOPOLOGY_FAMILIES = ("static", "erdos_renyi", "pairwise", "dropout")

# Above this client count, materializing an (n, n) mixing matrix is a silent
# O(n²) scaling bug — the sparse neighbor-list path exists precisely so that
# per-round cost grows with edge count instead.  The dense samplers raise at
# trace time (n is static) rather than quietly allocating.
DENSE_MATERIALIZATION_LIMIT = 512


def check_dense_materialization(n: int, what: str) -> None:
    """Raise if ``what`` would materialize an (n, n) array past the limit."""
    if n > DENSE_MATERIALIZATION_LIMIT:
        raise ValueError(
            f"{what} would materialize a dense ({n}, {n}) mixing matrix "
            f"(limit {DENSE_MATERIALIZATION_LIMIT}); use "
            f"repro.core.sparse_topology / mixing_impl='sparse_packed' "
            f"for large client counts")

# fold_in stream ids separating the W draw from the participation-mask draw
# (the data sampler's streams are the raw per-round key and 999; these are
# disjoint by construction since they fold a second constant).
W_STREAM = 1717
MASK_STREAM = 2929


def round_stream_key(key, round_idx, stream: int):
    """Per-(round, stream) PRNG key: ``fold_in(fold_in(key, round), stream)``.

    The same discipline as ``engine.sampler.make_dro_sampler`` — every draw
    is a pure function of (seed key, round index, stream id), which is what
    makes checkpoint restore resume the exact W/mask sequence.
    """
    return jax.random.fold_in(jax.random.fold_in(key, round_idx), stream)


def metropolis_weights(adj) -> jnp.ndarray:
    """Metropolis–Hastings weights for a symmetric (n, n) adjacency.

    w_ij = 1/(1 + max(d_i, d_j)) on edges, diagonal takes the leftover
    mass — symmetric doubly stochastic for any symmetric adjacency,
    including empty or disconnected graphs (isolated nodes get w_ii = 1).
    Traceable analogue of ``topology.metropolis`` (that one is host-side
    numpy with Python loops).
    """
    adj = adj.astype(jnp.float32)
    n = adj.shape[0]
    adj = adj * (1.0 - jnp.eye(n, dtype=jnp.float32))
    deg = adj.sum(1)
    w = adj / (1.0 + jnp.maximum(deg[:, None], deg[None, :]))
    return w + jnp.diag(1.0 - w.sum(1))


def erdos_renyi_w(key, n: int, edge_prob) -> jnp.ndarray:
    """One G(n, edge_prob) draw -> MH-weighted mixing matrix.

    ``edge_prob`` may be traced (uniform-threshold sampling).

    Draws **one canonical uniform per undirected edge** on the same
    convention as the sparse sampler
    (``sparse_topology.make_sparse_w_sampler``): a (n, n−1) uniform where
    row i's slot s is the draw for i's s-th neighbor in its ascending
    full-graph neighbor list, and edge {i, j} reads the draw of its
    lower-indexed endpoint — slot j−1 of row i for j > i.  Same key, same
    shape, same comparison, so a dense ER draw and a sparse ER draw on the
    full-graph support realize the identical edge set (parity-pinned by
    tests/test_adversary.py).
    """
    check_dense_materialization(n, "erdos_renyi_w")
    if n < 2:
        return jnp.eye(max(n, 1), dtype=jnp.float32)
    u = jax.random.uniform(key, (n, n - 1))
    # pad[i, j] = u[i, j-1] for j ≥ 1: slot j−1 of row i is edge {i, j}, j > i
    pad = jnp.concatenate([jnp.zeros((n, 1), u.dtype), u], axis=1)
    upper = jnp.triu(pad < edge_prob, k=1)
    return metropolis_weights(upper | upper.T)


def pairwise_w(key, n: int) -> jnp.ndarray:
    """Randomized pairwise gossip: W = I − ½(e_i−e_j)(e_i−e_j)ᵀ for one
    uniformly random pair i ≠ j; degenerates to I for n < 2."""
    if n < 2:
        return jnp.eye(max(n, 1), dtype=jnp.float32)
    ki, kj = jax.random.split(key)
    i = jax.random.randint(ki, (), 0, n)
    j = jax.random.randint(kj, (), 0, n - 1)
    j = j + (j >= i).astype(j.dtype)
    d = (jax.nn.one_hot(i, n, dtype=jnp.float32)
         - jax.nn.one_hot(j, n, dtype=jnp.float32))
    return jnp.eye(n, dtype=jnp.float32) - 0.5 * jnp.outer(d, d)


def masked_w(w, mask) -> jnp.ndarray:
    """Self-loop fallback: W′_ij = W_ij·m_i·m_j off-diagonal, each diagonal
    absorbs its row's lost mass (W′_ii = 1 − Σ_{j≠i} W′_ij).

    For any 0/1 mask this keeps W′ symmetric, nonnegative, and doubly
    stochastic, and collapses a masked-out client's row/column to e_i — it
    neither sends nor receives, so (W′θ)_i = θ_i and (W′Δ)_i = Δ_i exactly.
    """
    w = jnp.asarray(w, jnp.float32)
    n = w.shape[0]
    check_dense_materialization(n, "masked_w")
    m = mask.astype(jnp.float32)
    off = w * (1.0 - jnp.eye(n, dtype=jnp.float32)) * m[:, None] * m[None, :]
    return off + jnp.diag(1.0 - off.sum(1))


def bernoulli_mask(key, n: int, rate) -> jnp.ndarray:
    """(n,) bool mask, P[active] = rate (traced ok; rate ≥ 1 → all active)."""
    return jax.random.uniform(key, (n,)) < rate


def make_w_sampler(
    family: str,
    n: int,
    key,
    *,
    base_w: Optional[np.ndarray] = None,
    edge_prob=0.5,
    client_drop_prob=0.3,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """``w_fn(round_idx) -> (n, n) f32 W``: this round's mixing matrix.

    Pure and jit-traceable under a traced ``round_idx`` — the engine calls
    it inside the scanned chunk (sampler slot, see
    ``engine.sampler.with_topology``); ``repro.sweep`` calls it with
    per-trajectory traced ``edge_prob``/``client_drop_prob`` scalars.
    ``base_w`` is required for the ``static`` and ``dropout`` families (the
    matrix churn is applied to).
    """
    if family not in TOPOLOGY_FAMILIES:
        raise ValueError(
            f"unknown topology family {family!r}: {TOPOLOGY_FAMILIES}")
    if family in ("static", "dropout"):
        if base_w is None:
            raise ValueError(f"topology family {family!r} needs base_w")
        w0 = jnp.asarray(base_w, jnp.float32)
    if family == "static":
        return lambda round_idx: w0
    if family == "erdos_renyi":
        return lambda r: erdos_renyi_w(
            round_stream_key(key, r, W_STREAM), n, edge_prob)
    if family == "pairwise":
        return lambda r: pairwise_w(round_stream_key(key, r, W_STREAM), n)

    def sample_dropout(r):
        keep = bernoulli_mask(
            round_stream_key(key, r, W_STREAM), n, 1.0 - client_drop_prob)
        return masked_w(w0, keep)

    return sample_dropout


def make_participation_sampler(
    n: int, key, rate
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """``mask_fn(round_idx) -> (n,) bool`` per-round participation mask,
    drawn on the MASK_STREAM so it is independent of the same round's W
    draw.  ``rate`` may be traced (a sweep axis)."""
    return lambda r: bernoulli_mask(
        round_stream_key(key, r, MASK_STREAM), n, rate)
