"""Communication topologies and their mixing matrices (Assumption 4).

All matrices are symmetric, doubly stochastic, nonnegative.  ``spectral_gap``
returns the paper's ``p``: the largest p with ||XW - X̄||_F² <= (1-p)||X - X̄||_F²,
i.e. p = 1 - rho(W - J)² where rho is the spectral radius.
"""
from __future__ import annotations

import numpy as np


def ring(n: int) -> np.ndarray:
    """Each node: 1/3 self, 1/3 each neighbor (n=1,2 degenerate but valid)."""
    if n == 1:
        return np.ones((1, 1))
    if n == 2:
        return np.full((2, 2), 0.5)
    w = np.zeros((n, n))
    for i in range(n):
        w[i, i] = 1 / 3
        w[i, (i + 1) % n] = 1 / 3
        w[i, (i - 1) % n] = 1 / 3
    return w


def torus(n: int) -> np.ndarray:
    """2D wrap-around grid (n must be a perfect square); 1/5 self + neighbors."""
    s = int(round(np.sqrt(n)))
    if s * s != n:
        raise ValueError(f"torus needs a square n, got {n}")
    if s <= 2:
        return ring(n)
    w = np.zeros((n, n))
    for r in range(s):
        for c in range(s):
            i = r * s + c
            for dr, dc in ((0, 0), (1, 0), (-1, 0), (0, 1), (0, -1)):
                j = ((r + dr) % s) * s + (c + dc) % s
                w[i, j] += 1 / 5
    return w


def fully_connected(n: int) -> np.ndarray:
    return np.full((n, n), 1.0 / n)


def exponential(n: int) -> np.ndarray:
    """Exponential graph: node i connects to i +- 2^k; Metropolis weights."""
    adj = np.zeros((n, n), bool)
    k = 1
    while k < n:
        for i in range(n):
            adj[i, (i + k) % n] = adj[i, (i - k) % n] = True
        k *= 2
    np.fill_diagonal(adj, False)
    return metropolis(adj)


def star(n: int) -> np.ndarray:
    adj = np.zeros((n, n), bool)
    adj[0, 1:] = adj[1:, 0] = True
    return metropolis(adj)


def metropolis(adj: np.ndarray) -> np.ndarray:
    n = adj.shape[0]
    deg = adj.sum(1)
    w = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if adj[i, j]:
                w[i, j] = 1.0 / (1 + max(deg[i], deg[j]))
        w[i, i] = 1.0 - w[i].sum()
    return w


TOPOLOGIES = {
    "ring": ring,
    "torus": torus,
    "full": fully_connected,
    "exp": exponential,
    "star": star,
}


def mixing_matrix(topology: str, n: int) -> np.ndarray:
    try:
        w = TOPOLOGIES[topology](n)
    except KeyError:
        raise KeyError(f"unknown topology {topology!r}: {sorted(TOPOLOGIES)}") from None
    assert np.allclose(w, w.T) and np.allclose(w.sum(1), 1.0) and (w >= -1e-12).all()
    return w


def spectral_gap(w: np.ndarray) -> float:
    """p in Assumption 4: 1 - max_{i>=2} |lambda_i(W)|^2."""
    eig = np.sort(np.abs(np.linalg.eigvalsh(w)))[::-1]
    rho = eig[1] if len(eig) > 1 else 0.0
    return float(1.0 - rho**2)
