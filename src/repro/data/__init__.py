from repro.data.synthetic import (  # noqa: F401
    DataModel,
    heterogeneity_index,
    make_data_model,
    round_batches,
    sample_client_batch,
)
