"""Synthetic heterogeneous federated token data.

Each of G *domains* has its own unigram model plus a distinct bigram shift;
each client draws sequences from a client-specific Dirichlet(alpha) mixture
over domains.  ``alpha`` directly controls inter-client heterogeneity
(alpha -> 0: disjoint domains per client; alpha -> inf: iid clients), which is
the quantity the paper's heterogeneity-robustness claim is about.

Group labels (the domain of each sequence) feed the DRO objective's
per-group losses.

Sampling is a *pure function of (key, client)* built entirely from jax
primitives, so batches can be drawn inside ``jit`` — the execution engine
(``repro.engine``) calls ``round_batches`` from within a ``lax.scan`` body
with a traced round index, generating each round's data on device instead
of transferring it from host.  ``DataModel`` is registered as a pytree so
it crosses jit boundaries as data (arrays) + static metadata.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataModel:
    domain_logits: jnp.ndarray     # (G, V) unigram logits per domain
    domain_shift: jnp.ndarray      # (G,) bigram shift per domain
    mixtures: jnp.ndarray          # (n_clients, G) client domain mixtures
    vocab_size: int
    num_groups: int


jax.tree_util.register_dataclass(
    DataModel,
    data_fields=["domain_logits", "domain_shift", "mixtures"],
    meta_fields=["vocab_size", "num_groups"],
)


def make_data_model(
    key,
    *,
    vocab_size: int,
    num_groups: int = 8,
    num_clients: int = 4,
    alpha: float = 0.3,
    sharpness: float = 2.0,
) -> DataModel:
    # k3 (vocab-tile noise) and k4 (Dirichlet mixtures) used to be the same
    # key — fixed in PR 3, which shifts sampled mixtures for a given seed
    # (regression-pinned in tests/test_data.py).
    k1, k2, k3, k4 = jax.random.split(key, 4)
    logits = sharpness * jax.random.normal(k1, (num_groups, min(vocab_size, 4096)))
    if vocab_size > 4096:  # tile to the full vocab, cheap + deterministic
        reps = -(-vocab_size // 4096)
        logits = jnp.tile(logits, (1, reps))[:, :vocab_size]
        logits = logits + 0.01 * jax.random.normal(k3, (num_groups, 1))
    shift = jax.random.randint(k2, (num_groups,), 1, max(2, vocab_size // 7))
    mix = jax.random.dirichlet(k4, jnp.full((num_groups,), alpha), (num_clients,))
    return DataModel(
        domain_logits=logits,
        domain_shift=shift,
        mixtures=mix,
        vocab_size=vocab_size,
        num_groups=num_groups,
    )


def sample_client_batch(dm: DataModel, key, client: int, batch: int, seq_len: int,
                        num_codebooks: int = 0):
    """One client's batch: {"tokens","labels","groups"}.

    tokens: (B, S[+1 truncated]) — labels are next-token; groups: (B, S) the
    sequence's domain id.  Bigram structure: t_{s+1} depends on t_s via a
    domain-specific shift, so models can actually learn per-domain structure.
    """
    # kg: domain draw; kt: token draws; kb: bigram/unigram mask.  kg used to
    # double as kb — fixed in PR 3 (see tests/test_data.py for the pinned
    # post-fix key-splitting scheme).
    kg, kt, kb = jax.random.split(key, 3)
    g = jax.random.categorical(kg, jnp.log(dm.mixtures[client] + 1e-9), shape=(batch,))
    if num_codebooks:
        toks = jax.random.categorical(
            kt, dm.domain_logits[g][:, None, :],
            shape=(num_codebooks, batch, seq_len + 1)).transpose(1, 2, 0)
        shift = dm.domain_shift[g][:, None, None]
        labels_full = (toks + shift) % dm.vocab_size
        tokens = toks[:, :-1]
        labels = labels_full[:, 1:]
    else:
        first = jax.random.categorical(kt, dm.domain_logits[g], shape=(seq_len + 1, batch)).T
        shift = dm.domain_shift[g][:, None]
        # blend unigram draws with the bigram-shift of the previous token
        prev = jnp.roll(first, 1, axis=1).at[:, 0].set(first[:, 0])
        use_bigram = jax.random.bernoulli(kb, 0.5, first.shape)
        seq = jnp.where(use_bigram, (prev + shift) % dm.vocab_size, first)
        tokens, labels = seq[:, :-1], seq[:, 1:]
    groups = jnp.broadcast_to(g[:, None], (batch, seq_len)).astype(jnp.int32)
    return {"tokens": tokens, "labels": labels, "groups": groups}


def round_batches(
    dm: DataModel,
    key,
    *,
    local_steps: int,
    num_clients: int,
    per_client_batch: int,
    seq_len: int,
    cfg: Optional[ModelConfig] = None,
):
    """Batches for one round, stacked (K, n, B, S…) — the shape round_step eats."""
    ncb = cfg.num_codebooks if cfg is not None else 0
    keys = jax.random.split(key, local_steps * num_clients)
    keys = keys.reshape(local_steps, num_clients, 2)

    def one(k, i):
        b = sample_client_batch(dm, k, i, per_client_batch, seq_len, ncb)
        if cfg is not None and cfg.num_prefix_tokens:
            kp = jax.random.fold_in(k, 7)
            b["prefix"] = 0.02 * jax.random.normal(
                kp, (per_client_batch, cfg.num_prefix_tokens, cfg.d_model))
        return b

    return jax.vmap(lambda ks: jax.vmap(one)(ks, jnp.arange(num_clients)))(keys)


def heterogeneity_index(dm: DataModel) -> float:
    """Mean pairwise TV distance between client mixtures (0 = iid clients)."""
    m = dm.mixtures
    n = m.shape[0]
    tv = 0.5 * jnp.abs(m[:, None, :] - m[None, :, :]).sum(-1)
    return float(tv.sum() / (n * (n - 1) + 1e-9))
