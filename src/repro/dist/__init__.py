"""Distribution subsystem: sharding specs + activation-constraint context.

``repro.dist`` is the glue between the *algorithm* layer (``repro.core`` —
pure pytree transforms with a leading clients dim) and the *hardware* layer
(the meshes in ``repro.launch.mesh``).  It answers two questions:

1. **Where does each parameter live?**  ``repro.dist.sharding`` maps
   parameter pytrees to :class:`jax.sharding.NamedSharding`\\s: on the
   decentralized training mesh the leading clients dim goes on the
   ``clients`` axis (so per-client compute never crosses a client boundary
   and only the K-GT-Minimax gossip communicates between clients), and each
   client's shard is FSDP-2D sharded over its private ``(fsdp, model)``
   sub-mesh.

2. **Where do activations live?**  ``repro.dist.context`` is a thread-local
   stack of *tagged* sharding-constraint functions that the model stack
   (``repro.models``) consults via :func:`apply` / :func:`apply_residual`.
   The model code stays mesh-agnostic; step builders in
   ``repro.launch.steps`` install the layout (residual sharding per
   ``MeshConfig.residual_mode``, optional attention head-sharding) with the
   :func:`residual_constraint` context manager around tracing.

``repro.dist.compat`` papers over jax API drift (``jax.set_mesh`` /
``AxisType`` only exist on newer jax) so the same launch code runs on the
CPU containers used for tests and on real TPU pods.
"""
from repro.dist.compat import abstract_mesh, make_mesh, mesh_of, use_mesh
from repro.dist.context import (
    apply,
    apply_residual,
    current_slots,
    residual_constraint,
)
from repro.dist.sharding import (
    CLIENTS,
    FSDP,
    MODEL,
    leading_dims_constraint,
    params_shardings,
    residual_axes,
    serve_params_shardings,
)

__all__ = [
    "CLIENTS",
    "FSDP",
    "MODEL",
    "abstract_mesh",
    "apply",
    "apply_residual",
    "current_slots",
    "leading_dims_constraint",
    "make_mesh",
    "mesh_of",
    "params_shardings",
    "residual_axes",
    "residual_constraint",
    "serve_params_shardings",
    "use_mesh",
]
