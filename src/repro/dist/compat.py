"""jax version compatibility shims for the mesh/sharding layer.

The launch code targets the newest mesh API (``jax.set_mesh``, explicit
``AxisType``) but must also run on the jax 0.4.x wheels baked into the CPU
test containers, where neither exists.  All version probing lives here so
``repro.launch`` and ``repro.dist.sharding`` can stay branch-free.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types (Auto lets GSPMD propagate freely)
    from jax.sharding import AxisType

    _AUTO = AxisType.Auto
except ImportError:  # jax 0.4.x: every axis is implicitly auto
    AxisType = None
    _AUTO = None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with all axes ``Auto``, on any supported jax.

    Used for the production mesh (``repro.launch.mesh``) and for the
    CPU-backed fake meshes in tests/smoke runs (``XLA_FLAGS=
    --xla_force_host_platform_device_count=N`` before first jax init).
    """
    if _AUTO is not None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=(_AUTO,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def mesh_of(devices: np.ndarray, axis_names: Sequence[str]) -> Mesh:
    """Wrap an explicit device array in a Mesh with ``Auto`` axes.

    This is the decentralized-mesh constructor: the caller reshapes the
    production device array to ``(clients, fsdp, model)`` so one K-GT-Minimax
    client owns each contiguous ``fsdp x model`` block (see
    ``repro.launch.mesh.make_decentralized_mesh``).
    """
    names = tuple(axis_names)
    if _AUTO is not None:
        return Mesh(devices, names, axis_types=(_AUTO,) * len(names))
    return Mesh(devices, names)


def use_mesh(mesh: Mesh):
    """Context manager entering ``mesh`` (``jax.set_mesh`` when available).

    Inside the context, jit tracing and sharding-constraint resolution treat
    ``mesh`` as the ambient mesh.  On jax 0.4.x a ``Mesh`` is itself a
    context manager with the same meaning, so we return it directly.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def abstract_mesh(axis_sizes: Mapping[str, int]):
    """Device-free :class:`jax.sharding.AbstractMesh` for spec-level work.

    Lets tests and planners build ``NamedSharding``\\s for meshes larger than
    the local device count (e.g. asserting the clients-axis placement of
    :func:`repro.dist.sharding.params_shardings` on a 1-CPU container).
    Handles the two AbstractMesh constructor generations.
    """
    from jax.sharding import AbstractMesh

    items = tuple(axis_sizes.items())
    try:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(items)
    except TypeError:  # jax >= 0.5: AbstractMesh(sizes, names)
        return AbstractMesh(tuple(s for _, s in items),
                            tuple(n for n, _ in items))
