"""Thread-local distribution context: tagged activation-constraint switches.

The model stack (``repro.models``) is written once, mesh-agnostic.  Layout
decisions — where the residual stream lives, whether attention shards heads
over ``model`` — belong to the step builders in ``repro.launch.steps``,
which know the mesh and the ``MeshConfig``.  This module is the conduit: a
builder wraps tracing in :func:`residual_constraint`, registering constraint
functions under string tags; the model calls :func:`apply` at the tagged
program points (``transformer.block_forward``: ``"attn_qkv"`` after the QKV
projection, ``"attn_out"`` before the out-projection) and
:func:`apply_residual` after each scanned unit.  With no context installed
every call is the identity, so plain CPU tests and the single-device
serving demo run the exact same model code with zero sharding machinery.

The stack is *thread-local* because jit tracing happens on the calling
thread: two threads AOT-compiling different meshes (e.g. the dry-run
driving train and serve builds) cannot observe each other's slots.  Frames
nest innermost-wins per tag, falling through to outer frames for tags the
inner one doesn't define.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Dict, Optional

ConstraintFn = Callable[[Any], Any]

# Slot name used for the residual-stream constraint (``apply_residual``).
RESIDUAL = "residual"

_tls = threading.local()


def _stack():
    s = getattr(_tls, "stack", None)
    if s is None:
        s = _tls.stack = []
    return s


def current_slots() -> Dict[str, ConstraintFn]:
    """Effective tag -> constraint mapping (outer frames shadowed by inner).

    Diagnostic / test helper; the hot path is :func:`apply`.
    """
    out: Dict[str, ConstraintFn] = {}
    for frame in _stack():
        out.update(frame)
    return out


def apply(tag: str, x):
    """Apply the innermost constraint registered under ``tag``, or identity.

    Called from traced model code, so the lookup must be cheap and must not
    capture tracers: the constraint fns themselves close over the mesh and
    ``PartitionSpec`` only (see
    ``repro.dist.sharding.leading_dims_constraint``).
    """
    for frame in reversed(_stack()):
        fn = frame.get(tag)
        if fn is not None:
            return fn(x)
    return x


def apply_residual(x):
    """Re-pin the residual stream to the installed layout (identity if none).

    The model stack calls this once per scanned unit so the residual's
    sharding — ``(fsdp, model)`` or ``(fsdp,)`` per
    ``MeshConfig.residual_mode``, see ``repro.dist.sharding.residual_axes``
    — stays fixed across ``lax.scan`` iterations instead of drifting with
    GSPMD propagation.
    """
    return apply(RESIDUAL, x)


@contextlib.contextmanager
def residual_constraint(residual: Optional[ConstraintFn] = None,
                        **slots: ConstraintFn):
    """Install constraint functions for the dynamic extent of a trace.

    ``residual`` becomes the :func:`apply_residual` target; keyword slots
    register additional tagged switches (``attn_qkv`` / ``attn_out`` for the
    Megatron-SP-style ``attn_heads_sharding`` option).  Usage, from
    ``repro.launch.steps.build_train_round``::

        with dist_ctx.residual_constraint(constraint, **head_slots):
            return round_fn(state, batches, keys)   # traced under jit

    Re-entrant: nested ``with`` blocks shadow outer tags and restore them on
    exit, so a serving builder can temporarily override only the residual
    while keeping an ambient head-sharding slot.
    """
    frame = dict(slots)
    if residual is not None:
        frame[RESIDUAL] = residual
    stack = _stack()
    stack.append(frame)
    try:
        yield
    finally:
        stack.pop()
