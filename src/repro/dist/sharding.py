"""Parameter sharding specs for the decentralized and production meshes.

Axis vocabulary (see ``repro.launch.mesh``):

* Decentralized training mesh ``(clients, fsdp, model)`` — one K-GT-Minimax
  client per contiguous ``fsdp x model`` block.  Every algorithm-state leaf
  carries a leading clients dim ``n`` (``repro.core.kgt_minimax``); mapping
  that dim onto the ``clients`` axis is what confines each client's K local
  DRO-minimax steps to its own sub-mesh — the only cross-client collectives
  left in the compiled HLO are the two gossips per round (lines 7–8 and
  10–11 of Algorithm 1), which is the paper's communication-efficiency claim
  realized as a sharding invariant.
* Production serving mesh ``(data, model)`` or ``(pod, data, model)`` —
  plain tensor-parallel inference: weights sharded over ``model``,
  replicated over the batch axes.

Within a client, ``param_mode`` picks the layout: ``"fsdp2d"`` shards each
weight over ``(fsdp, model)`` (the default: tracking state cx/cy is fp32 and
client-stacked, so per-device memory is the binding constraint — see the
internvl2 note in ``repro.launch.mesh``); ``"replicated"`` keeps weights
client-replicated (fastest for small models where gather latency dominates).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# Canonical axis names of the decentralized logical mesh.
CLIENTS = "clients"
FSDP = "fsdp"
MODEL = "model"

# MoE expert-weight leaves: (..., experts, d_in, d_out); the experts dim sits
# at ndim-3 whether or not the tree carries clients/repeat leading dims.
_EXPERT_LEAF_KEYS = frozenset({"gate", "up", "down"})


def _axis_sizes(mesh) -> dict:
    """{axis_name: size} for a concrete Mesh or an AbstractMesh."""
    return dict(mesh.shape)


def _best_dim(shape: Tuple[int, ...], used, axis_size: int) -> Optional[int]:
    """Largest dim divisible by ``axis_size`` (ties -> later dim, i.e. the
    matmul output end of a weight), or None if nothing shardable."""
    cands = [(sz, i) for i, sz in enumerate(shape)
             if i not in used and sz > 1 and sz >= axis_size
             and sz % axis_size == 0]
    return max(cands)[1] if cands else None


def _is_expert_leaf(path) -> bool:
    """True for MoE expert weights (stacked ``(…, e, d, f)`` leaves under a
    ``"moe"`` dict key) — the leaves ``moe_expert_parallel`` maps onto the
    ``model`` axis so dispatch lowers to all-to-alls."""
    keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    return "moe" in keys and keys[-1] in _EXPERT_LEAF_KEYS


def params_shardings(
    params,
    mesh,
    *,
    leading_clients: bool = True,
    param_mode: str = "fsdp2d",
    expert_parallel: bool = False,
):
    """Map a parameter pytree to ``NamedSharding``\\s on the decentralized mesh.

    Args:
      params: pytree of arrays or ``ShapeDtypeStruct``\\s.  With
        ``leading_clients=True`` every leaf is the client-stacked algorithm
        state of ``repro.core.kgt_minimax.KGTState`` (``(n, …)``); dim 0 is
        pinned to the ``clients`` mesh axis so gossip is the only
        cross-client traffic.
      mesh: the ``(clients, fsdp, model)`` mesh (or an AbstractMesh with the
        same axis names, for device-free spec computation).
      leading_clients: whether leaf dim 0 is the clients dim.
      param_mode: ``"fsdp2d"`` — within each client, shard the largest
        remaining dim over ``model`` and the next over ``fsdp`` (ZeRO-3-like
        2D layout; GSPMD inserts the per-layer gathers).  ``"replicated"`` —
        leave weights whole within a client.
      expert_parallel: additionally pin the experts dim of MoE expert
        weights to ``model`` (expert parallelism; the measured win for the
        MoE archs, see the ``expert_parallel`` dry-run variant).

    Returns a pytree of ``NamedSharding`` congruent with ``params``.  A dim
    is only sharded when its size divides the axis extent, so the same specs
    work on tiny CPU fake meshes (axis sizes 1–2) and full pods.
    """
    sizes = _axis_sizes(mesh)

    def spec_for(path, leaf):
        shape = tuple(leaf.shape)
        parts = [None] * len(shape)
        used = set()
        if leading_clients and shape:
            parts[0] = CLIENTS
            used.add(0)
        if param_mode != "replicated":
            if expert_parallel and _is_expert_leaf(path) and len(shape) >= 3:
                e_dim = len(shape) - 3
                if (e_dim not in used and shape[e_dim] % sizes[MODEL] == 0
                        and shape[e_dim] >= sizes[MODEL]):
                    parts[e_dim] = MODEL
                    used.add(e_dim)
            for axis in (MODEL, FSDP):
                if axis in parts:
                    continue
                d = _best_dim(shape, used, sizes[axis])
                if d is not None:
                    parts[d] = axis
                    used.add(d)
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def serve_params_shardings(params, mesh, *, expert_parallel: bool = False):
    """Tensor-parallel inference shardings on the production mesh.

    Weights shard their largest divisible dim over ``model`` and replicate
    over the batch axes (``data`` / ``pod``): activations on the serving
    path are batch-over-``data`` and seq-over-``model`` (sequence
    parallelism — see ``repro.launch.steps.build_prefill_step``), so
    model-axis TP keeps every matmul's weight shard resident with its
    activation shard and no weight ever crosses the pod boundary.
    """
    sizes = _axis_sizes(mesh)
    n_model = sizes.get("model", 1)

    def spec_for(path, leaf):
        shape = tuple(leaf.shape)
        parts = [None] * len(shape)
        used = set()
        if expert_parallel and _is_expert_leaf(path) and len(shape) >= 3:
            e_dim = len(shape) - 3
            if shape[e_dim] % n_model == 0 and shape[e_dim] >= n_model:
                parts[e_dim] = "model"
                used.add(e_dim)
        if "model" not in parts:
            d = _best_dim(shape, used, n_model)
            if d is not None:
                parts[d] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(spec_for, params)


# ---------------------------------------------------------------------------
# Activation (residual-stream) constraints
# ---------------------------------------------------------------------------

def residual_axes(residual_mode: str) -> Tuple[str, ...]:
    """Mesh axes for the leading dims of the residual stream, per
    ``MeshConfig.residual_mode``.

    ``"batch_seq"`` (default): batch over ``fsdp``, sequence over ``model``
    — full 2D activation sharding; GSPMD gathers the sequence dim around
    attention.  ``"batch"``: batch over ``fsdp`` only, sequence replicated —
    trades activation memory for the seq gathers (the ``batch_residual``
    dry-run variant).
    """
    if residual_mode == "batch":
        return (FSDP,)
    if residual_mode == "batch_seq":
        return (FSDP, MODEL)
    raise ValueError(f"unknown residual_mode: {residual_mode!r}")


def leading_dims_constraint(mesh, axes: Sequence[Optional[str]]):
    """Constraint fn sharding the first ``len(axes)`` dims of ``x`` by ``axes``.

    This is what step builders install as the ``residual`` slot of
    ``repro.dist.context``: the model stack calls
    :func:`repro.dist.context.apply_residual` once per scanned unit
    (``repro.models.transformer.stack_forward``), re-pinning the residual
    stream so GSPMD's propagation can't drift layouts across scan
    iterations.  Arrays with fewer dims than ``axes`` pass through.
    """
    axes = tuple(axes)

    def fn(x):
        if x.ndim < len(axes):
            return x
        spec = P(*axes, *([None] * (x.ndim - len(axes))))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return fn
