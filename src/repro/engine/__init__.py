"""Chunked multi-round execution engine (see ``docs/architecture.md``,
"The execution engine").

``engine`` — scan-over-rounds chunk programs, the chunk driver, hooks.
``sampler`` — device-side per-round batch samplers.
``diagnostics`` — metric functions for the streaming metrics buffer.
"""
from repro.engine.engine import (  # noqa: F401
    checkpoint_hook,
    chunk_program,
    make_chunk_builder,
    records_from_buffer,
    row_to_record,
    run,
    split_sampled,
    telemetry_hook,
    timed_chunk_builder,
)
from repro.engine.diagnostics import (  # noqa: F401
    dro_metrics_fn,
    quadratic_metrics_fn,
)
from repro.engine.sampler import (  # noqa: F401
    held_out_eval_batch,
    make_dro_sampler,
    make_fixed_batch_sampler,
    with_topology,
)
