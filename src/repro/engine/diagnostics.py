"""Metric functions for the engine's streaming diagnostics buffer.

A metrics function has signature ``(state, batches) -> {name: array}`` —
``batches`` is the round's K-stacked training data (so train-side metrics
see exactly what the optimizer saw), every value is a fixed-shape array
(scalars or small vectors like per-group losses), and the whole dict is one
row of the fixed-size on-device buffer the engine fills inside ``lax.scan``.

Builders here cover the two problem families in the repo; custom callers
(e.g. ``examples/adversarial_training.py``) write their own inline.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import kgt_minimax as kgt
from repro.core import mixing as mixing_lib
from repro.core.minimax import MinimaxProblem


def _consensus_block(state) -> Dict[str, jnp.ndarray]:
    """The state-health metrics every run wants: consensus Ξx/Ξy, the
    Lemma-8 ‖c̄‖ watchdogs for both corrections, and the ȳ norm
    (``correction_mean_norm`` is exactly the client-mean L2 norm, applied
    here to y)."""
    return {
        "consensus_x": mixing_lib.consensus_error(state.x),
        "consensus_y": mixing_lib.consensus_error(state.y),
        "corr_x_norm": kgt.correction_mean_norm(state.cx),
        "corr_y_norm": kgt.correction_mean_norm(state.cy),
        "y_bar_norm": kgt.correction_mean_norm(state.y),
    }


def dro_metrics_fn(
    problem: MinimaxProblem,
    model_cfg: ModelConfig,
    *,
    num_groups: int,
    eval_batch: Optional[Any] = None,
    compute_dtype=jnp.bfloat16,
):
    """Metrics for DRO-LM training (what ``repro.launch.train`` logs).

    Train-side: f(x̄, ȳ) and the mean per-group loss on the round's own
    first (k=0, client 0) batch.  Eval-side (when ``eval_batch`` is given —
    a fixed held-out batch from ``repro.engine.sampler.held_out_eval_batch``):
    mean and per-group losses of the consensus model on data the optimizer
    never trains on.
    """
    from repro.models import per_group_loss

    def metrics(state, batches) -> Dict[str, jnp.ndarray]:
        xbar = kgt.mean_over_clients(state.x)
        ybar = state.y.mean(0)
        train_b = jax.tree.map(lambda b: b[0, 0], batches)  # (k=0, client 0)
        train_losses, _ = per_group_loss(
            xbar, train_b, model_cfg, num_groups=num_groups,
            compute_dtype=compute_dtype)
        out = {
            "f_bar": problem.value(xbar, ybar, train_b, None),
            "mean_loss": train_losses.mean(),
            **_consensus_block(state),
        }
        if eval_batch is not None:
            eval_losses, _ = per_group_loss(
                xbar, eval_batch, model_cfg, num_groups=num_groups,
                compute_dtype=compute_dtype)
            out["eval_loss"] = eval_losses.mean()
            out["eval_group_loss"] = eval_losses  # (G,) vector row
        return out

    return metrics


def quadratic_metrics_fn(problem: MinimaxProblem):
    """Metrics for the synthetic NC-SC quadratic: the exact ‖∇Φ(x̄)‖ oracle
    the theory-validation benchmarks track, plus the consensus block."""

    def metrics(state, batches) -> Dict[str, jnp.ndarray]:
        del batches
        xbar = kgt.mean_over_clients(state.x)
        return {
            "phi_grad_norm": problem.phi_grad_norm(xbar),
            **_consensus_block(state),
        }

    return metrics
