"""Chunked scan-over-rounds execution engine.

The host training loop pays per-round costs that have nothing to do with
Algorithm 1: host-side batch sampling, host→device transfer, one jit
dispatch per round, and a blocking metrics read.  For the
thousands-of-rounds × K-local-steps trajectories the paper's experiments
run, that overhead dominates wall-clock on fast hardware.

This engine compiles **R-round chunks as a single XLA program**:

  * ``lax.scan`` over ``round_step`` — one dispatch per R rounds;
  * a device-side *sampler* ``(round_idx) -> (batches, keys)`` called inside
    the scan body, so each round's data is generated on device
    (``repro.engine.sampler``; no per-round host→device transfer);
  * *streaming diagnostics* — a fixed-size on-device metrics buffer
    ``(mask, rounds, rows)`` of length R, filled every ``log_every`` rounds
    by ``metrics_fn`` inside the scan (a ``lax.cond`` skips the compute on
    non-logged rounds) and read back **once per chunk**;
  * chunk-boundary *hooks* (checkpointing, …).  ``state.round`` is the
    single source of truth: the sampler, the lr schedule (``lr_scale``
    inside ``round_step``), and the metrics gating are all functions of it,
    so a restored checkpoint resumes the identical trajectory.

Layering: this module is algorithm- and problem-agnostic — it only needs a
``round_step(state, batches, keys) -> state`` with an integer
``state.round`` field, a sampler, and (optionally) a metrics function
returning a flat ``{name: array}`` dict.  ``repro.launch.train`` drives the
DRO-LM runs through it, ``repro.launch.steps.build_train_chunk`` compiles
the same chunk program with donated sharded state over the decentralized
mesh, and ``benchmarks/``/``examples/`` consume it for the paper-toy
trajectories.
"""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# (round_idx) -> (batches, keys) or (batches, keys, extras): a sampler may
# return a third element — a tuple of per-round traced operands (a sampled
# mixing matrix W, a participation mask; see sampler.with_topology) that the
# chunk body splats into round_step(state, batches, keys, *extras).
Sampler = Callable[[jnp.ndarray], Tuple[Any, ...]]
MetricsFn = Callable[[Any, Any], Dict[str, jnp.ndarray]]
Hook = Callable[[Any, List[dict], int], None]  # (state, records, prev_round)


def split_sampled(sampled) -> Tuple[Any, Any, Tuple[Any, ...]]:
    """One sampler return -> ``(batches, keys, extras)`` per the Sampler
    protocol above.  Every consumer of a sampler (the scanned chunk body,
    the host A/B loops) goes through this so the two execution paths can't
    drift on the protocol."""
    batches, keys = sampled[0], sampled[1]
    extras = tuple(sampled[2]) if len(sampled) > 2 else ()
    return batches, keys, extras


def chunk_program(
    round_step: Callable[[Any, Any, Any], Any],
    sampler: Sampler,
    metrics_fn: Optional[MetricsFn] = None,
    *,
    log_every: int = 1,
    length: int,
):
    """Builds ``chunk_step(state, final_round) -> (state, buffer)``.

    ``buffer`` is ``None`` when ``metrics_fn`` is None, else the fixed-size
    on-device triple ``(mask (R,), rounds (R,), rows {name: (R, …)})``.
    A row is filled when the round index hits the ``log_every`` grid or
    equals ``final_round`` (so the last round of a run always logs) —
    matching the host driver's ``t % log_every == 0 or t == rounds-1``.
    """
    log_every = max(int(log_every), 1)

    def chunk_step(state, final_round):
        def body(st, _):
            batches, keys, extras = split_sampled(sampler(st.round))
            new_st = round_step(st, batches, keys, *extras)
            if metrics_fn is None:
                return new_st, None
            do_log = jnp.logical_or(st.round % log_every == 0,
                                    st.round == final_round)
            shapes = jax.eval_shape(metrics_fn, new_st, batches)
            zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
            row = jax.lax.cond(
                do_log, lambda: metrics_fn(new_st, batches), lambda: zeros)
            return new_st, (do_log, st.round, row)

        state, buf = jax.lax.scan(body, state, None, length=length)
        return state, buf

    return chunk_step


def make_chunk_builder(
    round_step: Callable[[Any, Any, Any], Any],
    sampler: Sampler,
    metrics_fn: Optional[MetricsFn] = None,
    *,
    log_every: int = 1,
    donate: bool = True,
    jit_fn=None,
):
    """Returns ``build(length) -> jitted chunk_step``, caching per length.

    A run needs at most two lengths (full chunks + one remainder), so the
    cache stays tiny.  ``jit_fn(fn)`` overrides how the program is staged —
    ``build_train_chunk`` passes a mesh-aware jit with sharded/donated
    state; the default is a plain ``jax.jit`` with the state donated.
    """
    cache: Dict[int, Any] = {}

    def build(length: int):
        if length not in cache:
            fn = chunk_program(round_step, sampler, metrics_fn,
                               log_every=log_every, length=length)
            if jit_fn is not None:
                cache[length] = jit_fn(fn)
            else:
                cache[length] = jax.jit(
                    fn, donate_argnums=(0,) if donate else ())
        return cache[length]

    return build


def timed_chunk_builder(build_chunk: Callable[[int], Any], *,
                        cache=None, statics=None):
    """Wraps ``build(length)`` so compilation is timed apart from execution.

    The first call at each length goes through the jit AOT path
    (``fn.lower(*args).compile()``) with the elapsed time accumulated into
    ``wrapper.stats["compile_s"]``; subsequent calls hit the compiled
    executable directly.  This is what lets ``run`` / the benchmarks report
    steady-state ``run_s`` instead of folding first-chunk compilation into
    every rounds/s and time-to-ε number.

    ``cache`` (a ``repro.sweep.cache.CompileCache``) routes that AOT step
    through the persistent executable cache: the first call per length
    looks up ``(statics + length, arg avals)`` on disk and deserializes
    instead of compiling when warm.  The deserialize seconds are accumulated
    into ``compile_s`` (it is the get-an-executable cost the split exists to
    isolate), so a warm run reports compile_s ≈ milliseconds — the cache's
    own hit/miss/byte stats live on ``cache.stats``.  ``statics`` must name
    every value baked into the chunk program as a closure constant (see the
    cache module docstring); callers that cannot enumerate those must not
    pass a cache.

    When the built function has no ``lower`` (a plain Python callable) or
    lowering fails (exotic jit wrappers), the whole first call — compile
    *and* its one execution — is attributed to ``compile_s``; for the
    multi-second XLA programs this wrapper exists to time, the execution
    share of that first call is noise.
    """
    wrapped: Dict[int, Any] = {}
    stats = {"compile_s": 0.0}

    def build(length: int):
        if length in wrapped:
            return wrapped[length]
        fn = build_chunk(length)
        holder: List[Any] = []

        def call(*args):
            if not holder:
                if cache is not None:
                    compiled, info = cache.get_or_compile(
                        "chunk", (statics, ("length", length)), fn, args)
                    stats["compile_s"] += (info["compile_s"]
                                           + info["deserialize_s"])
                    holder.append(compiled)
                    return holder[0](*args)
                t0 = time.perf_counter()
                compiled = None
                lower = getattr(fn, "lower", None)
                if lower is not None:
                    try:
                        compiled = lower(*args).compile()
                    except Exception:
                        compiled = None
                if compiled is not None:
                    holder.append(compiled)
                    stats["compile_s"] += time.perf_counter() - t0
                else:
                    holder.append(fn)
                    out = fn(*args)
                    jax.block_until_ready(out)
                    stats["compile_s"] += time.perf_counter() - t0
                    return out
            return holder[0](*args)

        wrapped[length] = call
        return call

    build.stats = stats
    return build


def row_to_record(row: Dict[str, Any], round_idx: int) -> dict:
    """One metrics row (host-side arrays) -> a plain-python history record:
    scalars become floats, vectors (e.g. per-group losses) become lists.
    Shared by the chunk read-back below and the per-round host loop so both
    execution models emit byte-identical record structures."""
    rec: dict = {"round": int(round_idx)}
    for name, v in row.items():
        v = np.asarray(v)
        rec[name] = float(v) if v.ndim == 0 else v.tolist()
    return rec


def records_from_buffer(buf) -> List[dict]:
    """Device metrics buffer -> list of plain-python history records.

    One transfer for the whole chunk; rows where the mask is unset (rounds
    that were not on the log grid) are dropped.
    """
    if buf is None:
        return []
    mask, rounds, rows = jax.device_get(buf)
    records = []
    for i in range(mask.shape[0]):
        if not bool(mask[i]):
            continue
        records.append(row_to_record(
            {name: col[i] for name, col in rows.items()}, rounds[i]))
    return records


def run(
    state,
    build_chunk: Callable[[int], Any],
    *,
    total_rounds: int,
    chunk_rounds: int,
    hooks: Sequence[Hook] = (),
    stop_fn: Optional[Callable[[List[dict]], bool]] = None,
    wall_clock: bool = True,
    boundary_every: Optional[int] = None,
    telemetry=None,
):
    """Drives chunks from ``state.round`` up to ``total_rounds``.

    Host work per chunk: one dispatch, one metrics read-back, hooks.  The
    resume point is read from ``state.round`` (a restored checkpoint picks
    up exactly where it left off).  Hooks are called at every chunk boundary
    as ``hook(state, records, prev_round)`` where ``prev_round`` is the
    round count before the chunk ran.  ``boundary_every=N`` splits chunks so
    a boundary lands on every multiple of N — pass the checkpoint cadence
    so ``checkpoint_hook`` fires at the exact requested rounds regardless
    of chunk alignment.  ``stop_fn(records) -> bool`` enables early exit at
    chunk boundaries (benchmarks' rounds-to-ε loops).

    Returns ``(state, history)`` with history records as produced by
    ``records_from_buffer``.  Unless disabled, each record carries three
    wall-clock stamps: ``wall_s`` (total elapsed), ``compile_s`` (XLA
    compilation incurred by this run so far, measured via
    :func:`timed_chunk_builder`), and the steady-state
    ``run_s = wall_s - compile_s`` — so rounds/s numbers derived from the
    history no longer fold first-chunk compilation in.  A repeat ``run``
    with the same builder reuses its compiled executables and stamps
    ``compile_s`` ≈ 0.

    ``telemetry`` (a ``repro.obs.events.Telemetry``, or anything with the
    same ``span``/``span_event`` surface) wraps each chunk's dispatch and
    metrics read-back in monotonic-clock spans and emits a ``compile`` span
    whenever a chunk incurred XLA compilation.  ``None`` (the default) is
    the zero-overhead path: no telemetry object is ever touched and the
    executed program is byte-identical to pre-telemetry behavior.
    """
    chunk_rounds = max(int(chunk_rounds), 1)
    if hasattr(build_chunk, "stats"):
        build = build_chunk
    else:
        # memoize the wrapper on the builder: a second run() with the same
        # builder (checkpoint-restore resume, back-to-back benchmark runs)
        # must reuse the compiled executables, not AOT-compile afresh
        build = getattr(build_chunk, "_timed", None)
        if build is None:
            build = timed_chunk_builder(build_chunk)
            try:
                build_chunk._timed = build
            except AttributeError:
                pass
    history: List[dict] = []
    start = int(state.round)
    final_round = jnp.int32(total_rounds - 1)
    t0 = time.perf_counter()
    compile_before = build.stats["compile_s"]
    r = start
    while r < total_rounds:
        length = min(chunk_rounds, total_rounds - r)
        if boundary_every:
            next_boundary = (r // boundary_every + 1) * boundary_every
            length = min(length, next_boundary - r)
        if telemetry is None:
            state, buf = build(length)(state, final_round)
            records = records_from_buffer(buf)
        else:
            comp_prev = build.stats["compile_s"]
            with telemetry.span("dispatch", round=r, length=length):
                state, buf = build(length)(state, final_round)
            comp_delta = build.stats["compile_s"] - comp_prev
            if comp_delta > 0:
                # compilation happens inside the first call at each length
                # (timed_chunk_builder's AOT path) — surface it as its own
                # span so dispatch time reads as steady-state
                telemetry.span_event("compile", comp_delta,
                                     round=r, length=length)
            with telemetry.span("readback", round=r):
                records = records_from_buffer(buf)
        if wall_clock:
            wall = time.perf_counter() - t0
            # only compilation incurred by THIS run: the builder (and its
            # stats) may be shared across runs, while t0 is per-run
            comp = build.stats["compile_s"] - compile_before
            for rec in records:
                # 3-decimal stamps: 1-decimal rounding collapsed sub-100ms
                # chunks to wall_s=0.0; run_s clamps at 0 because compile_s
                # is measured around the AOT build while wall spans this
                # run, so tiny first-chunk runs could go negative
                rec["wall_s"] = round(wall, 3)
                rec["compile_s"] = round(comp, 3)
                rec["run_s"] = round(max(wall - comp, 0.0), 3)
        history.extend(records)
        for hook in hooks:
            hook(state, records, r)
        r += length
        if stop_fn is not None and stop_fn(records):
            break
    return state, history


def telemetry_hook(telemetry, *, ledger=None, health_fn=None,
                   health_every: int = 1) -> Hook:
    """Chunk-boundary telemetry: the sibling of :func:`checkpoint_hook`.

    Per boundary, emits into ``telemetry`` (``repro.obs.events.Telemetry``):

    * one ``metrics`` event per history record of the chunk (the streamed
      diagnostics rows, verbatim);
    * a ``ledger`` event when a ``repro.obs.ledger.CommLedger`` is given —
      the chunk's analytically-accounted communication plus running totals
      (``ledger.add_rounds`` is driven here, from ``state.round``);
    * the ``health_fn(state) -> {name: float}`` gauges (e.g.
      ``repro.obs.profiler.health_gauges``: Σc drift, consensus, EF residual
      norms), sampled every ``health_every``-th boundary.

    Everything is host-side.  ``health_fn`` is the only part that touches
    the device (a few tiny reductions + one small transfer per sample) —
    pass ``None`` to keep the run dispatch-identical to an untelemetered
    one; the hook itself never alters the trajectory either way.
    """
    state_holder = {"boundaries": 0}

    def hook(state, records, prev_round):
        for rec in records:
            telemetry.metrics(rec)
        if ledger is not None:
            rounds = int(state.round) - int(prev_round)
            if rounds > 0:
                ledger.add_rounds(rounds)
                telemetry.emit(ledger.event(rounds=rounds,
                                            round=int(state.round)))
        if health_fn is not None:
            b = state_holder["boundaries"]
            state_holder["boundaries"] = b + 1
            if b % max(int(health_every), 1) == 0:
                for name, value in health_fn(state).items():
                    telemetry.gauge(name, value, round=int(state.round))

    return hook


def checkpoint_hook(directory: str, every: int, metadata: Optional[dict] = None,
                    verbose: bool = False) -> Hook:
    """Chunk-boundary checkpointing: saves when the boundary crosses a
    multiple of ``every`` rounds (with the engine, checkpoints land on chunk
    boundaries — ``state.round`` in the filename/metadata keeps the resume
    point exact regardless of alignment).  A boundary can cross several
    multiples at once; pass ``boundary_every=every`` to ``run`` to split
    chunks at the exact multiples (``launch/train`` does)."""
    from repro.checkpoint import checkpoint as ckpt_lib

    def hook(state, records, prev_round):
        r = int(state.round)
        if not every or r // every <= prev_round // every:
            return
        path = os.path.join(directory, f"round_{r:06d}.npz")
        meta = dict(metadata or {})
        meta["round"] = r
        ckpt_lib.save(path, state, metadata=meta)
        if verbose:
            print(f"[engine] checkpoint -> {path}", flush=True)

    return hook
