"""Device-side per-round batch samplers for the chunked engine.

A *sampler* is a pure, jit-traceable function ``(round_idx) -> (batches,
keys)`` producing exactly what ``round_step`` eats: batches stacked
``(K, n, B, S…)`` and per-(local step, client) PRNG keys ``(K, n, 2)``.
Called inside the engine's ``lax.scan`` body with the traced
``state.round``, so data generation happens on device and the round loop
needs zero host→device transfers.

The DRO sampler reproduces the host driver's historical key schedule
(``kb = fold_in(round_key, t)``; batch keys from ``kb``, oracle keys from
``fold_in(kb, 999)``) bit-for-bit, which is what makes the
engine-vs-host-loop trajectory equality in ``tests/test_engine.py`` exact.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data import synthetic as data_lib


def make_dro_sampler(
    dm: data_lib.DataModel,
    round_key,
    *,
    local_steps: int,
    num_clients: int,
    per_client_batch: int,
    seq_len: int,
    cfg: Optional[ModelConfig] = None,
):
    """Sampler over a heterogeneous synthetic ``DataModel``.

    ``round_key`` seeds the whole data stream; round ``t`` draws from
    ``fold_in(round_key, t)`` so any round's batch is reproducible in
    isolation (checkpoint restore at round r resamples the same data).
    """

    def sample(round_idx):
        kb = jax.random.fold_in(round_key, round_idx)
        batches = data_lib.round_batches(
            dm, kb, local_steps=local_steps, num_clients=num_clients,
            per_client_batch=per_client_batch, seq_len=seq_len, cfg=cfg)
        keys = jax.random.split(
            jax.random.fold_in(kb, 999), local_steps * num_clients
        ).reshape(local_steps, num_clients, 2)
        return batches, keys

    return sample


def make_fixed_batch_sampler(batches, *, local_steps: int, num_clients: int,
                             seed: int = 0):
    """Sampler over a fixed K-stacked batch (the synthetic quadratic
    benchmarks: the 'data' is the per-client problem slice, stochasticity
    enters through the oracle keys).

    Key schedule matches ``benchmarks.common`` historically:
    ``PRNGKey(seed * 7919 + t)`` split into (K, n, 2).
    """

    def sample(round_idx):
        keys = jax.random.split(
            jax.random.PRNGKey(seed * 7919 + round_idx),
            local_steps * num_clients,
        ).reshape(local_steps, num_clients, 2)
        return batches, keys

    return sample


def with_topology(sampler, *, w_fn=None, mask_fn=None, attack_fn=None):
    """Rides the churn and adversary axes on the engine's sampler slot:
    wraps a batch sampler so each round also draws that round's mixing
    matrix, participation mask, and/or Byzantine adversary
    (``repro.core.stochastic_topology`` / ``repro.core.adversary`` samplers
    — pure functions of the round index on the same ``fold_in`` discipline
    as the data draw, so checkpoint restore replays the identical
    W/mask/attack sequence).

    The wrapped sampler returns ``(batches, keys, extras)``; the engine
    splats ``extras`` into ``round_step(state, batches, keys, *extras)`` in
    the order (W, mask, adversary) — matching ``make_round_step(traced_w=...,
    participation=..., byzantine=...)``'s extra-operand order.
    """
    fns = tuple(f for f in (w_fn, mask_fn, attack_fn) if f is not None)
    if not fns:
        raise ValueError(
            "with_topology needs w_fn, mask_fn, and/or attack_fn")

    def sample(round_idx):
        sampled = sampler(round_idx)
        if len(sampled) > 2:
            raise ValueError(
                "with_topology: the wrapped sampler already returns extras; "
                "compose all per-round draws into a single wrapper instead "
                "of nesting (the inner draws would be silently dropped)")
        batches, keys = sampled
        return batches, keys, tuple(f(round_idx) for f in fns)

    return sample


def held_out_eval_batch(
    dm: data_lib.DataModel,
    key,
    *,
    num_clients: int,
    per_client_batch: int,
    seq_len: int,
    cfg: Optional[ModelConfig] = None,
):
    """One fixed client-balanced eval batch, sampled once from the
    ``DataModel`` (never from the training stream): one ``per_client_batch``
    draw per client distribution, flattened to ``(n·B, S…)``."""
    rb = data_lib.round_batches(
        dm, key, local_steps=1, num_clients=num_clients,
        per_client_batch=per_client_batch, seq_len=seq_len, cfg=cfg)
    return jax.tree.map(
        lambda x: x.reshape((x.shape[1] * x.shape[2],) + x.shape[3:]), rb)
