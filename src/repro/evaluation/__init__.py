from repro.evaluation.metrics import evaluate_clients, group_metrics  # noqa: F401
