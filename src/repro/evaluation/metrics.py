"""Evaluation metrics for decentralized DRO training.

Worst-group loss is the quantity DRO optimizes implicitly (the y-ascent
soft-maximizes hard groups); per-group perplexity exposes the robustness
the paper's minimax formulation buys over ERM.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib


def group_metrics(params, batch, cfg: ModelConfig, *, num_groups: int,
                  compute_dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Per-group CE/ppl + worst-group stats on one batch."""
    losses, _ = model_lib.per_group_loss(
        params, batch, cfg, num_groups=num_groups, compute_dtype=compute_dtype)
    present = jax.nn.one_hot(batch["groups"], num_groups).sum((0, 1)) > 0
    masked = jnp.where(present, losses, -jnp.inf)
    worst = jnp.max(masked)
    return {
        "group_loss": losses,
        "group_ppl": jnp.exp(jnp.clip(losses, 0, 20.0)),
        "mean_loss": jnp.where(present, losses, 0.0).sum() / jnp.maximum(
            present.sum(), 1),
        "worst_group_loss": worst,
        "worst_group": jnp.argmax(masked),
        "groups_present": present.sum(),
    }


def evaluate_clients(state_x, dm, cfg: ModelConfig, key, *, num_groups: int,
                     per_client_batch: int = 4, seq_len: int = 128,
                     compute_dtype=jnp.bfloat16) -> Dict[str, float]:
    """Evaluate the consensus model x̄ on every client's distribution —
    the federated metric that matters (robustness across clients)."""
    from repro.data import synthetic as data_lib

    xbar = jax.tree.map(lambda x: x.mean(0), state_x)
    n = dm.mixtures.shape[0]
    worst_client = -jnp.inf
    means = []
    for i in range(n):
        b = data_lib.sample_client_batch(
            dm, jax.random.fold_in(key, i), i, per_client_batch, seq_len,
            cfg.num_codebooks)
        m = group_metrics(xbar, b, cfg, num_groups=num_groups,
                          compute_dtype=compute_dtype)
        means.append(m["mean_loss"])
        worst_client = jnp.maximum(worst_client, m["mean_loss"])
    return {
        "client_mean_loss": float(jnp.stack(means).mean()),
        "worst_client_loss": float(worst_client),
    }
