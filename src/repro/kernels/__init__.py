from repro.kernels.ops import (  # noqa: F401
    flash_attention,
    fused_cross_entropy,
    rglru_scan,
    ssd_scan,
)
