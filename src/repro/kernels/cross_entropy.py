"""Fused big-vocab cross-entropy Pallas kernel (TPU target, interpret-mode
validated).

Computes per-token NLL without ever materializing (N, V) logits: the grid is
(token_blocks, vocab_blocks); each step does one (BT, d) x (d, BV) MXU tile
of the head matmul and folds it into online log-sum-exp scratch, capturing
the label logit when the label falls inside the tile.  VMEM per step:
BT·d (hidden) + d·BV (weight tile) + (BT, BV) logits tile — the same
blocking the fused-CE memory fix in ``repro.models.model.chunked_nll`` does
at the XLA level, here tiled for VMEM/MXU explicitly (this was the single
largest memory lever found in §Perf: 12.8 -> 5.8 GiB on qwen2 train).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(h_ref, w_ref, lab_ref, nll_ref, m_scr, l_scr, ll_scr, *,
            block_v, vocab):
    vi = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        ll_scr[...] = jnp.full_like(ll_scr, NEG_INF)

    h = h_ref[...].astype(jnp.float32)            # (BT, d)
    w = w_ref[...].astype(jnp.float32)            # (BV, d)
    logits = jax.lax.dot_general(
        h, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (BT, BV)

    # mask padded vocab columns
    col = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(col < vocab, logits, NEG_INF)

    # capture the label logit if it lives in this tile
    lab = lab_ref[...]                             # (BT,)
    hit = col == lab[:, None]
    ll_scr[...] = jnp.maximum(
        ll_scr[...], jnp.max(jnp.where(hit, logits, NEG_INF), axis=1))

    # online log-sum-exp
    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, logits.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_scr[...] * alpha + jnp.exp(
        logits - m_cur[:, None]).sum(axis=1)
    m_scr[...] = m_cur

    @pl.when(vi == nv - 1)
    def _finish():
        lse = jnp.log(jnp.maximum(l_scr[...], 1e-30)) + m_scr[...]
        nll_ref[...] = (lse - ll_scr[...]).astype(nll_ref.dtype)


def fused_ce_nd(hidden, weight, labels, *, block_t: int = 128,
                block_v: int = 512, interpret: bool = True):
    """hidden: (N, d); weight: (V, d) (tied-embedding layout); labels: (N,).
    Returns per-token NLL (N,) float32.  N and V are padded to the blocks."""
    n, d = hidden.shape
    v = weight.shape[0]
    bt = min(block_t, n)
    bv = min(block_v, v)
    n_pad = (-n) % bt
    v_pad = (-v) % bv
    if n_pad:
        hidden = jnp.pad(hidden, ((0, n_pad), (0, 0)))
        labels = jnp.pad(labels, (0, n_pad))
    if v_pad:
        weight = jnp.pad(weight, ((0, v_pad), (0, 0)))
    nt = (n + n_pad) // bt
    nv = (v + v_pad) // bv

    kernel = functools.partial(_kernel, block_v=bv, vocab=v)
    out = pl.pallas_call(
        kernel,
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),  # running max
            pltpu.VMEM((bt,), jnp.float32),  # running sum
            pltpu.VMEM((bt,), jnp.float32),  # label logit
        ],
        interpret=interpret,
    )(hidden, weight, labels)
    return out[:n]
