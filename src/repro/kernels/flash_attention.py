"""Flash attention Pallas kernel (TPU target, validated in interpret mode).

Online-softmax attention with causal and sliding-window masking and native
GQA (kv heads indexed from the q-head grid coordinate — no kv replication in
HBM).  Tiling: the grid is (batch*q_heads, q_blocks, kv_blocks); TPU iterates
the minor-most (kv) dimension sequentially per (head, q-block), so the
running max/sum/accumulator live in VMEM scratch across kv steps.

Block shapes are (BQ, D) / (BK, D) with D padded to the MXU lane width by the
wrapper in ``repro.kernels.ops``; BQ = BK = 128 by default (128x128 MXU tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale,
            seq_len, block_q, block_k, window, causal):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (BQ, D)
    k = k_ref[0].astype(jnp.float32)          # (BK, D)
    v = v_ref[0].astype(jnp.float32)          # (BK, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                  # (BQ, BK)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_len                     # padded keys never attend
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                        # (BQ,)
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    # fully-masked rows (early q rows under a window) contribute nothing
    p = jnp.where(mask, p, 0.0)

    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q, k, v, *, causal: bool = True, window: int = 0, scale=None,
    block_q: int = 128, block_k: int = 128, interpret: bool = True,
):
    """q: (BH, Sq, D); k, v: (BKV, Sk, D) with BH % BKV == 0 (GQA groups).

    Sq/Sk must be pre-padded to multiples of the block sizes; ``seq_len`` is
    taken as k's true length (padding handled by callers via the key mask).
    """
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    assert bh % bkv == 0, (bh, bkv)
    group = bh // bkv
    scale = (d ** -0.5) if scale is None else scale
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = sq // block_q
    nk = sk // block_k

    grid = (bh, nq, nk)
    kernel = functools.partial(
        _kernel, scale=scale, seq_len=sk, block_q=block_q, block_k=block_k,
        window=window, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max
            pltpu.VMEM((block_q,), jnp.float32),   # running sum
            pltpu.VMEM((block_q, d), jnp.float32), # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
