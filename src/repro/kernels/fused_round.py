"""Whole-round fused Pallas kernel (TPU target, validated in interpret).

``kernels/gossip.py`` fuses only the round *epilogue*; every one of the K
local SGDA steps still round-trips the client state through HBM, which is
why the epilogue-only lowering loses wall clock to plain dense XLA (the
pack/concat traffic outweighs the collective savings — see
results/benchmarks.json "gossip").  For the quadratic workload the local
step is **affine** in the packed state z = (x; y):

    (∇x f_i, ∇y f_i) = split(G_i z + h)        (MinimaxProblem.affine_coeffs)

so all K steps are K fused-multiply-adds against coefficients that fit in
VMEM — one kernel pass runs the entire Algorithm-1 round:

    repeat K:   z ← z − s ⊙ (G z + h_k + c)     (local SGDA; s = ±η_c ⊙ mask)
    Δ  = z_K − z₀
    q  = Δ                        (exact)    — or, compressed:
    v  = mask ⊙ (Δ + e);  q = Q(v);  e' = mask ? v − q : e
    z' = W z₀ + η_s ⊙ (W q)                    (parameter gossip + mixing)
    c' = c + corr ⊙ (q − W q)                  (tracking correction)

Per-column vectors ``s``/``η_s``/``corr`` carry the x/y split (opposite
descent/ascent signs, separate learning rates) and arrive as full
``(n, dz)`` f32 arrays — they are *traced* (lr schedules, churn masks), so
they ride in as operands rather than baked constants, and broadcasting them
host-side avoids scalar prefetch entirely.  ``corr = 0`` encodes the
no-tracking variants (c' = c exactly).  The correction is constant across
the K local steps (Algorithm 1 updates it only at the round boundary).

Compression uses the *same* ``kernels.quantize.quantize_dequant`` the
oracle and the core EF protocol import — three lowerings, one rounding
rule.  The transmitted q replaces Δ in both the mixing and the correction,
which is what preserves the Σc = 0 telescoping under any doubly stochastic
W (see ``core.compression``).

Memory: this kernel is grid-less — n is tiny (≤ a few hundred after the
sparse path takes over) and the G z contraction binds the full dz axis, so
every operand is a single VMEM block.  G is the big one: n·dz²·4 bytes
(8 MB at n=8, dz=512); ``ops.fused_round`` asserts dz_pad ≤ 1024 to stay
inside a TPU core's ~16 MB VMEM.

``gossip_dtype`` narrows only the W-matmul operands (the wire values), as
in ``kernels/gossip.py``; Δ/q stay f32 inside the correction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quantize import quantize_dequant


def _kernel(w_ref, z0_ref, c_ref, ef_ref, g_ref, h_ref, step_ref, etas_ref,
            corr_ref, mask_ref, z_out_ref, c_out_ref, e_out_ref, *,
            k_steps, compress, gossip_dtype):
    z0 = z0_ref[...].astype(jnp.float32)            # (N, DZ)
    c = c_ref[...].astype(jnp.float32)              # (N, DZ)
    step = step_ref[...]                            # (N, DZ)  ±η_c ⊙ mask
    g = g_ref[...]                                  # (N, DZ, DZ)
    # batched matvec: grad[i] = G[i] @ z[i]
    gdims = (((2,), (1,)), ((0,), (0,)))

    def body(k, z):
        grad = jax.lax.dot_general(g, z, gdims,
                                   preferred_element_type=jnp.float32)
        return z - step * (grad + h_ref[k] + c)

    zk = jax.lax.fori_loop(0, k_steps, body, z0)
    delta = zk - z0

    ef = ef_ref[...].astype(jnp.float32)
    if compress is None:
        q = delta                                    # mask already in step ⇒
        e_new = ef                                   # inactive Δ ≡ 0 exactly
    else:
        mask = mask_ref[...]
        v = mask * (delta + ef)                      # inactive: nothing on wire
        q = quantize_dequant(v, compress)
        e_new = jnp.where(mask > 0, v - q, ef)       # inactive residual frozen

    w = w_ref[...].astype(jnp.float32)               # (N, N)
    if gossip_dtype is None:
        wg, qg, zg = w, q, z0
    else:
        wg = w.astype(gossip_dtype)
        qg = q.astype(gossip_dtype)
        zg = z0.astype(gossip_dtype)
    wdims = (((1,), (0,)), ((), ()))
    wq = jax.lax.dot_general(wg, qg, wdims, preferred_element_type=jnp.float32)
    wz = jax.lax.dot_general(wg, zg, wdims, preferred_element_type=jnp.float32)
    z_out_ref[...] = wz + etas_ref[...] * wq
    c_out_ref[...] = c + corr_ref[...] * (q - wq)
    e_out_ref[...] = e_new


def fused_round_nd(w, z0, c, ef, g, h_steps, step, etas, corr, mask, *,
                   k_steps: int, compress=None, gossip_dtype=None,
                   interpret: bool = True):
    """w: (N, N); z0/c/ef/step/etas/corr/mask: (N, DZ) f32; g: (N, DZ, DZ);
    h_steps: (K, N, DZ).  N a sublane multiple, DZ a lane multiple (padding
    handled by ``ops.fused_round``).  Returns (z_new, c_new, ef_new) f32."""
    n, dz = z0.shape
    assert w.shape == (n, n), (w.shape, n)
    assert g.shape == (n, dz, dz), (g.shape, n, dz)
    assert h_steps.shape == (k_steps, n, dz), (h_steps.shape, k_steps, n, dz)
    for a in (c, ef, step, etas, corr, mask):
        assert a.shape == (n, dz), (a.shape, n, dz)

    kernel = functools.partial(_kernel, k_steps=k_steps, compress=compress,
                               gossip_dtype=gossip_dtype)
    out_sds = jax.ShapeDtypeStruct((n, dz), jnp.float32)
    # grid-less: every operand is one full VMEM block (see module docstring)
    return pl.pallas_call(
        kernel,
        out_shape=[out_sds, out_sds, out_sds],
        interpret=interpret,
    )(w, z0, c, ef, g, h_steps, step, etas, corr, mask)
