"""Fused gossip-epilogue Pallas kernel (TPU target, validated in interpret).

One kernel pass over the packed ``(n, D)`` client state computes the whole
round epilogue of Algorithm 1 (lines 7–11) for one variable:

    WΔ    = W @ Δ                      (the Δ-gossip, lines 7–8)
    Wθ    = W @ θ                      (the parameter gossip, lines 10–11)
    θ_new = Wθ + η_s · WΔ              (parameter mixing epilogue)
    c_new = c + s · (Δ − WΔ)           (tracking correction; s = ±1/(K·η_c))

Tiling: the grid is one program per D-tile; each program loads the full
``(n, n)`` mixing matrix W (n is the client count — tiny next to D) and an
``(n, BD)`` tile of Δ/θ/c, runs both matmuls on the MXU with f32
accumulation, and applies the epilogue in-register before the single write
back of θ_new/c_new.  The per-leaf lowering reads and writes every state
leaf 4+ times; this kernel reads Δ, θ, c once and writes θ_new, c_new once.

``gossip_dtype`` narrows only the matmul *operands* (what a multi-chip run
puts on the wire); Δ stays f32 inside the correction so the semantics match
``mixing.mix_dense`` + ``kgt_minimax._tree_axpy`` exactly.

Scalars (η_s, s) ride in via scalar prefetch — they are traced values
(η_c carries the lr schedule), so they cannot be baked into the kernel.

Callers go through ``repro.kernels.ops.fused_gossip_round``, which pads n
to the f32 sublane multiple and D to the lane/block multiple (ragged-D) and
slices the result back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, w_ref, delta_ref, theta_ref, c_ref, theta_out_ref,
            c_out_ref, *, gossip_dtype):
    eta_s = s_ref[0]
    corr_scale = s_ref[1]
    w = w_ref[...].astype(jnp.float32)              # (N, N)
    d32 = delta_ref[...].astype(jnp.float32)        # (N, BD)
    if gossip_dtype is None:
        wg, dg, tg = w, d32, theta_ref[...].astype(jnp.float32)
    else:
        wg = w.astype(gossip_dtype)
        dg = delta_ref[...].astype(gossip_dtype)
        tg = theta_ref[...].astype(gossip_dtype)
    dims = (((1,), (0,)), ((), ()))
    wd = jax.lax.dot_general(wg, dg, dims, preferred_element_type=jnp.float32)
    wt = jax.lax.dot_general(wg, tg, dims, preferred_element_type=jnp.float32)
    theta_out_ref[...] = (wt + eta_s * wd).astype(theta_out_ref.dtype)
    c_out_ref[...] = (c_ref[...].astype(jnp.float32)
                      + corr_scale * (d32 - wd)).astype(c_out_ref.dtype)


def fused_gossip_nd(w, delta, theta, c, scalars, *, block_d: int = 512,
                    gossip_dtype=None, interpret: bool = True):
    """w: (N, N); delta/theta/c: (N, D) with N a sublane multiple and D a
    ``block_d`` multiple (padding handled by ``ops.fused_gossip_round``);
    scalars: (2,) f32 = [η_s, corr_scale].  Returns (θ_new, c_new) f32."""
    n, d = delta.shape
    assert w.shape == (n, n) and theta.shape == c.shape == (n, d)
    block_d = min(block_d, d)
    assert d % block_d == 0, (d, block_d)

    kernel = functools.partial(_kernel, gossip_dtype=gossip_dtype)
    # index maps receive (grid indices, *scalar prefetch refs)
    tile = lambda i, *_: (0, i)
    out_sds = jax.ShapeDtypeStruct((n, d), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(d // block_d,),
            in_specs=[
                pl.BlockSpec((n, n), lambda i, *_: (0, 0)),  # W: every tile
                pl.BlockSpec((n, block_d), tile),            # Δ
                pl.BlockSpec((n, block_d), tile),            # θ
                pl.BlockSpec((n, block_d), tile),            # c
            ],
            out_specs=[
                pl.BlockSpec((n, block_d), tile),            # θ_new
                pl.BlockSpec((n, block_d), tile),            # c_new
            ],
        ),
        out_shape=[out_sds, out_sds],
        interpret=interpret,
    )(scalars, w, delta, theta, c)
