"""Sparse neighbor-gather gossip-epilogue Pallas kernel.

The dense kernel in ``kernels/gossip.py`` contracts the full ``(n, n)``
mixing matrix against each ``(n, BD)`` state tile — O(n²·D) work and O(n²)
VMEM for W, which caps the clients axis.  On a sparse topology W has only
``deg_i`` non-zeros per row, so this kernel computes the same Algorithm-1
round epilogue

    WΔ    = Σ_slot w[:, slot] · Δ[idx[:, slot]]     (neighbor-row gather)
    Wθ    = Σ_slot w[:, slot] · θ[idx[:, slot]]
    θ_new = Wθ + η_s · WΔ
    c_new = c + s · (Δ − WΔ)                        (s = ±1/(K·η_c))

by gathering neighbor rows from the packed ``(n, BD)`` tile — O(n·m·D)
work with ``m = max_degree + 1`` slots.  The wrapper
(``ops.sparse_gossip_round``) prepends an *augmented self slot*
(idx = own row, weight = w_ii), so the kernel body is one uniform
gather-axpy loop with no special diagonal case; padding slots carry
weight 0.0 and contribute exact zeros.

The slot loop is unrolled at trace time (m is static and small — ~2·log₂ n
for the exponential graph), each iteration a rank-1-in-slot broadcast
multiply on the VPU plus a dynamic row gather.

``gossip_dtype`` narrows the *operands* (weights and gathered values) and
accumulates in f32 — matching the MXU's exact-product bf16×bf16→f32
semantics of the dense kernel, so sparse and dense agree to accumulation
order.  Scalars (η_s, s) and the int32 neighbor table ride in via scalar
prefetch: the scalars are traced (lr schedule), and the indices must be
available to address generation ahead of the tile fetch.

TPU caveats (this container validates in interpret mode): the ``(n, m)``
int32 table lives in SMEM — at n=4096, m=25 that is ~400 KiB, near the
1 MiB SMEM budget, so very-large-n compiles may need the table split
across a client-axis grid; and per-row dynamic gathers lower to VMEM
dynamic slices, which Mosaic only supports on the sublane axis.  Callers
go through ``ops.sparse_gossip_round``, which pads n to the sublane
multiple and D to the lane/block multiple and slices back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, nidx_ref, nw_ref, delta_ref, theta_ref, c_ref,
            theta_out_ref, c_out_ref, *, gossip_dtype):
    eta_s = s_ref[0]
    corr_scale = s_ref[1]
    d32 = delta_ref[...].astype(jnp.float32)        # (N, BD)
    if gossip_dtype is None:
        dg, tg = d32, theta_ref[...].astype(jnp.float32)
        nw = nw_ref[...].astype(jnp.float32)        # (N, M)
    else:
        dg = delta_ref[...].astype(gossip_dtype)
        tg = theta_ref[...].astype(gossip_dtype)
        nw = nw_ref[...].astype(gossip_dtype)
    m = nw.shape[1]
    wd = jnp.zeros(d32.shape, jnp.float32)
    wt = jnp.zeros(d32.shape, jnp.float32)
    for slot in range(m):                           # static unroll
        idx = nidx_ref[:, slot]                     # (N,) int32, SMEM
        w_s = nw[:, slot].astype(jnp.float32)[:, None]
        wd = wd + w_s * jnp.take(dg, idx, axis=0).astype(jnp.float32)
        wt = wt + w_s * jnp.take(tg, idx, axis=0).astype(jnp.float32)
    theta_out_ref[...] = (wt + eta_s * wd).astype(theta_out_ref.dtype)
    c_out_ref[...] = (c_ref[...].astype(jnp.float32)
                      + corr_scale * (d32 - wd)).astype(c_out_ref.dtype)


def sparse_gossip_nd(neighbor_idx, neighbor_w, delta, theta, c, scalars, *,
                     block_d: int = 512, gossip_dtype=None,
                     interpret: bool = True):
    """neighbor_idx/neighbor_w: (N, M) *augmented* slots (slot 0 = self);
    delta/theta/c: (N, D) with N a sublane multiple and D a ``block_d``
    multiple (padding handled by ``ops.sparse_gossip_round``); scalars:
    (2,) f32 = [η_s, corr_scale].  Returns (θ_new, c_new) f32."""
    n, d = delta.shape
    m = neighbor_idx.shape[1]
    assert neighbor_idx.shape == (n, m) and neighbor_w.shape == (n, m)
    assert theta.shape == c.shape == (n, d)
    block_d = min(block_d, d)
    assert d % block_d == 0, (d, block_d)

    kernel = functools.partial(_kernel, gossip_dtype=gossip_dtype)
    # index maps receive (grid indices, *scalar prefetch refs)
    tile = lambda i, *_: (0, i)
    out_sds = jax.ShapeDtypeStruct((n, d), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,                   # scalars, neighbor_idx
            grid=(d // block_d,),
            in_specs=[
                pl.BlockSpec((n, m), lambda i, *_: (0, 0)),  # weights
                pl.BlockSpec((n, block_d), tile),            # Δ
                pl.BlockSpec((n, block_d), tile),            # θ
                pl.BlockSpec((n, block_d), tile),            # c
            ],
            out_specs=[
                pl.BlockSpec((n, block_d), tile),            # θ_new
                pl.BlockSpec((n, block_d), tile),            # c_new
            ],
        ),
        out_shape=[out_sds, out_sds],
        interpret=interpret,
    )(scalars, neighbor_idx, neighbor_w, delta, theta, c)
