"""Jit'd dispatch wrappers around the Pallas kernels.

Callers use model-layout tensors ((B, S, H, D) attention, (B, S, H, P) SSD);
these wrappers handle layout, GQA folding, block padding and the
pallas/interpret/xla backend choice.  On this CPU container the kernels run
in interpret mode for validation; ``backend="xla"`` routes to the pure-jnp
oracle (what the dry-run lowers); on real TPU ``interpret=False`` compiles
the kernels proper.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import fused_round as fround_lib
from repro.kernels import gossip as gossip_lib
from repro.kernels import neighbor_gossip as ngossip_lib
from repro.kernels import ref as ref_lib
from repro.kernels import rglru_scan as rg
from repro.kernels import ssd_scan as ssd


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@partial(jax.jit, static_argnames=("causal", "window", "backend", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    backend: str = "interpret", block_q: int = 128,
                    block_k: int = 128):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D).  Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    if backend == "xla":
        of = ref_lib.attention_ref(qf, kf, vf, causal=causal, window=window)
    else:
        qp, _ = _pad_to(qf, 1, block_q)
        kp, _ = _pad_to(kf, 1, block_k)
        vp, _ = _pad_to(vf, 1, block_k)
        of = fa.flash_attention_bhsd(
            qp, kp, vp, causal=causal, window=window, block_q=block_q,
            block_k=block_k, interpret=(backend == "interpret"))
        of = of[:, :sq]
    return of.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("chunk", "backend"))
def ssd_scan(xdt, loga, bm, cm, *, chunk: int = 64, backend: str = "interpret"):
    """xdt: (B, S, H, P); loga: (B, S, H); bm, cm: (B, S, N)."""
    b, s, h, p = xdt.shape
    xf = xdt.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    lf = loga.transpose(0, 2, 1).reshape(b * h, s)
    if backend == "xla":
        yf = ref_lib.ssd_ref(xf, lf, bm, cm)
    else:
        xf2, _ = _pad_to(xf, 1, chunk)
        lf2, _ = _pad_to(lf, 1, chunk)
        bm2, _ = _pad_to(bm, 1, chunk)
        cm2, _ = _pad_to(cm, 1, chunk)
        yf = ssd.ssd_scan_bh(xf2, lf2, bm2, cm2, chunk=chunk,
                             interpret=(backend == "interpret"))[:, :s]
    return yf.reshape(b, h, s, p).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block_t", "block_v", "backend"))
def fused_cross_entropy(hidden, weight, labels, *, block_t: int = 128,
                        block_v: int = 512, backend: str = "interpret"):
    """Per-token NLL without materializing (N, V) logits.
    hidden: (N, d); weight: (V, d); labels: (N,) int32."""
    if backend == "xla":
        return ref_lib.fused_ce_ref(hidden, weight, labels)
    from repro.kernels import cross_entropy as ce

    return ce.fused_ce_nd(hidden, weight, labels, block_t=block_t,
                          block_v=block_v, interpret=(backend == "interpret"))


GOSSIP_BACKENDS = ("auto", "pallas", "interpret", "xla")


def resolve_gossip_backend(backend: str) -> str:
    """"auto" -> the Pallas kernel on TPU, the packed-xla oracle elsewhere
    (interpret mode is for validation, far too slow for training loops; the
    xla oracle still gets the packed single-collective lowering on a mesh)."""
    if backend not in GOSSIP_BACKENDS:
        raise ValueError(f"unknown gossip_backend {backend!r}: {GOSSIP_BACKENDS}")
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# Measured-best block_d per packed shape, recorded by bench_gossip's one-time
# autotune sweep ({128, 256, 512, 1024} per (n, D)).  Resolution happens in
# the *unjitted* dispatchers below — block_d is a static argument, so it must
# be a concrete int before tracing.  Unmeasured shapes fall back to the old
# hardcoded-512 heuristic (clamped to the padded D).
_BLOCK_D_CACHE: dict = {}
BLOCK_D_CANDIDATES = (128, 256, 512, 1024)


def record_block_d(n: int, d: int, block_d: int) -> None:
    _BLOCK_D_CACHE[(int(n), int(d))] = int(block_d)


def best_block_d(n: int, d: int):
    """The measured winner for (n, D), or None if never autotuned."""
    return _BLOCK_D_CACHE.get((int(n), int(d)))


def _resolve_block_d(n: int, d: int, block_d) -> int:
    if block_d is None:
        block_d = _BLOCK_D_CACHE.get((n, d), 512)
    return min(block_d, max(128, -(-d // 128) * 128))


def _fused_gossip_body(w, delta, theta, c, eta_s, corr_scale, *,
                       backend: str, block_d: int, gossip_dtype):
    gd = (None if gossip_dtype in (None, "float32")
          else jnp.dtype(gossip_dtype))
    eta_s = jnp.float32(eta_s)
    corr_scale = jnp.float32(corr_scale)
    if backend == "xla":
        return ref_lib.fused_gossip_ref(w, delta, theta, c, eta_s,
                                        corr_scale, gossip_dtype=gd)
    n, d = delta.shape
    w = jnp.asarray(w, jnp.float32)
    wp, _ = _pad_to(w, 0, 8)
    wp, _ = _pad_to(wp, 1, 8)
    blk = block_d
    aligned = n % 8 == 0 and d % blk == 0

    def prep(x):
        x = x.astype(jnp.float32)
        if aligned:
            return x
        x, _ = _pad_to(x, 0, 8)
        x, _ = _pad_to(x, 1, blk)
        return x

    scalars = jnp.stack([eta_s, corr_scale])
    theta_new, c_new = gossip_lib.fused_gossip_nd(
        wp, prep(delta), prep(theta), prep(c), scalars, block_d=blk,
        gossip_dtype=gd, interpret=(backend == "interpret"))
    if aligned:
        return theta_new, c_new
    return theta_new[:n, :d], c_new[:n, :d]


_STATIC_GOSSIP = ("backend", "block_d", "gossip_dtype")
_fused_gossip_jit = jax.jit(_fused_gossip_body, static_argnames=_STATIC_GOSSIP)
# Donating variant: delta/theta/c are consumed (the packed round step builds
# fresh buffers each round, so their storage can back the outputs).  W is NOT
# donated — callers reuse it across the x- and y-variable calls of one round.
_fused_gossip_jit_donate = jax.jit(
    _fused_gossip_body, static_argnames=_STATIC_GOSSIP,
    donate_argnums=(1, 2, 3))


def fused_gossip_round(w, delta, theta, c, eta_s, corr_scale, *,
                       backend: str = "interpret", block_d=None,
                       gossip_dtype=None, donate: bool = False):
    """Fused round epilogue over packed client state.

    w: (n, n); delta/theta/c: (n, D).  Returns f32
    (θ_new, c_new) = (Wθ + η_s·WΔ, c + corr_scale·(Δ − WΔ)).

    ``gossip_dtype`` (None/str) narrows the matmul operands only.  The
    pallas/interpret path pads n to the f32 sublane multiple (8) and D to
    the block multiple with zeros — zero-padded W rows/cols contribute
    nothing — and slices back to (n, D); both copies are skipped when the
    shape is already aligned.  ``block_d=None`` uses the autotuned winner
    for this (n, D) if bench_gossip has recorded one, else 512.
    ``donate=True`` lets XLA reuse delta/theta/c storage for the outputs —
    only pass it when the caller holds the last reference to those buffers.
    Donation is honored only for concrete (non-traced) inputs on a backend
    that supports aliasing (TPU/GPU); under an outer jit the enclosing
    computation owns the buffers, and on CPU jax ignores donation with a
    "donated buffers were not usable" warning — both cases route to the
    plain variant so callers can pass donate=True unconditionally.
    """
    blk = _resolve_block_d(delta.shape[0], delta.shape[1], block_d)
    use_donate = (donate and not isinstance(delta, jax.core.Tracer)
                  and jax.default_backend() in ("tpu", "gpu"))
    fn = _fused_gossip_jit_donate if use_donate else _fused_gossip_jit
    return fn(w, delta, theta, c, eta_s, corr_scale, backend=backend,
              block_d=blk, gossip_dtype=gossip_dtype)


@partial(jax.jit, static_argnames=("backend", "compress", "gossip_dtype"))
def fused_round(w, z0, c, ef, g_mat, h_steps, step, etas, corr, mask, *,
                backend: str = "interpret", compress=None, gossip_dtype=None):
    """Whole Algorithm-1 round (K affine local SGDA steps + gossip epilogue)
    in one kernel pass over the packed z = (x; y) state.

    w: (n, n); z0/c/ef: (n, dz); g_mat: (n, dz, dz); h_steps: (K, n, dz);
    step/etas/corr/mask: (n, dz) broadcast per-column vectors (signs and
    masks pre-folded by the caller — see kernels/fused_round.py for the
    exact semantics).  Returns f32 (z_new, c_new, ef_new).

    ``compress`` (None / "bf16" / "int8") turns on error-feedback quantized
    gossip; ``ef`` is the carried residual (pass zeros when None — it flows
    through untouched).  The pallas/interpret path pads n → 8 and dz → 128
    with zeros (padded G rows/cols and masked rows contribute nothing) and
    slices back; ``backend="xla"`` routes to ``ref.fused_round_ref``.
    """
    gd = (None if gossip_dtype in (None, "float32")
          else jnp.dtype(gossip_dtype))
    if backend == "xla":
        return ref_lib.fused_round_ref(
            w, z0, c, ef, g_mat, h_steps, step, etas, corr, mask,
            compress=compress, gossip_dtype=gd)
    n, dz = z0.shape
    k_steps = h_steps.shape[0]
    dz_pad = max(128, -(-dz // 128) * 128)
    if dz_pad > 1024:
        raise ValueError(
            f"fused_round holds G (n·dz²·4 bytes) in one VMEM block; "
            f"dz_pad={dz_pad} > 1024 will not fit — use mixing_impl="
            f"'pallas_packed' for larger problems")
    wp, _ = _pad_to(jnp.asarray(w, jnp.float32), 0, 8)
    wp, _ = _pad_to(wp, 1, 8)

    def prep(x):
        x, _ = _pad_to(x.astype(jnp.float32), 0, 8)
        x, _ = _pad_to(x, 1, 128)
        return x

    gp, _ = _pad_to(g_mat.astype(jnp.float32), 0, 8)
    gp, _ = _pad_to(gp, 1, 128)
    gp, _ = _pad_to(gp, 2, 128)
    hp, _ = _pad_to(h_steps.astype(jnp.float32), 1, 8)
    hp, _ = _pad_to(hp, 2, 128)
    z_new, c_new, e_new = fround_lib.fused_round_nd(
        wp, prep(z0), prep(c), prep(ef), gp, hp, prep(step), prep(etas),
        prep(corr), prep(mask), k_steps=k_steps, compress=compress,
        gossip_dtype=gd, interpret=(backend == "interpret"))
    return z_new[:n, :dz], c_new[:n, :dz], e_new[:n, :dz]


@partial(jax.jit, static_argnames=("backend", "block_d", "gossip_dtype"))
def sparse_gossip_round(neighbor_idx, neighbor_w, self_w, delta, theta, c,
                        eta_s, corr_scale, *, backend: str = "interpret",
                        block_d: int = 512, gossip_dtype=None):
    """Fused round epilogue over packed client state, sparse W.

    neighbor_idx: (n, max_deg) int32 padded-CSR neighbor lists (padding =
    own index); neighbor_w: (n, max_deg) with padding weight 0; self_w:
    (n,) diagonal; delta/theta/c: (n, D).  Returns f32
    (θ_new, c_new) = (Wθ + η_s·WΔ, c + corr_scale·(Δ − WΔ)) — the same
    contract as ``fused_gossip_round`` at O(n·max_deg·D) instead of
    O(n²·D).  Raw arrays, not a ``SparseTopology``: callers unpack the
    pytree so the kernels package stays free of core imports.

    The pallas/interpret path prepends the augmented self slot (slot 0 =
    own row at weight w_ii), pads n to the f32 sublane multiple (padded
    rows gather row 0 at weight 0.0 — contribute nothing) and D to the
    block multiple, and slices back to (n, D).
    """
    gd = (None if gossip_dtype in (None, "float32")
          else jnp.dtype(gossip_dtype))
    eta_s = jnp.float32(eta_s)
    corr_scale = jnp.float32(corr_scale)
    if backend == "xla":
        return ref_lib.sparse_gossip_ref(
            neighbor_idx, neighbor_w, self_w, delta, theta, c, eta_s,
            corr_scale, gossip_dtype=gd)
    n, d = delta.shape
    own = jnp.arange(n, dtype=jnp.int32)[:, None]
    aidx = jnp.concatenate([own, neighbor_idx.astype(jnp.int32)], axis=1)
    aw = jnp.concatenate(
        [self_w.astype(jnp.float32)[:, None],
         neighbor_w.astype(jnp.float32)], axis=1)
    aidx, _ = _pad_to(aidx, 0, 8)
    aw, _ = _pad_to(aw, 0, 8)
    blk = min(block_d, max(128, -(-d // 128) * 128))

    def prep(x):
        x, _ = _pad_to(x.astype(jnp.float32), 0, 8)
        x, _ = _pad_to(x, 1, blk)
        return x

    scalars = jnp.stack([eta_s, corr_scale])
    theta_new, c_new = ngossip_lib.sparse_gossip_nd(
        aidx, aw, prep(delta), prep(theta), prep(c), scalars, block_d=blk,
        gossip_dtype=gd, interpret=(backend == "interpret"))
    return theta_new[:n, :d], c_new[:n, :d]


@partial(jax.jit, static_argnames=("chunk", "backend"))
def rglru_scan(a, u, *, chunk: int = 256, backend: str = "interpret"):
    """a, u: (B, S, W) -> h: (B, S, W)."""
    if backend == "xla":
        return ref_lib.rglru_ref(a, u)
    s = a.shape[1]
    a2, _ = _pad_to(a, 1, chunk)
    u2, _ = _pad_to(u, 1, chunk)
    # padded a=0 keeps the carry exact for the real rows
    return rg.rglru_scan_b(a2, u2, chunk=chunk,
                           interpret=(backend == "interpret"))[:, :s]
