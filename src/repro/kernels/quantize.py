"""Deterministic gossip quantizers (shared by the kernels and the oracles).

The compressed-gossip path transmits a quantize-dequantize image ``q =
Q(v)`` of the packed round delta and carries the *error-feedback* residual
``e = v − q`` into the next round (Sun & Wei's communication-efficient
federated minimax line, PAPERS.md arXiv 2206.01132).  Both quantizers here
are deterministic (no stochastic rounding) and satisfy the exactness
contract the EF state relies on:

    fl(v − Q(v)) == v − Q(v)   and   fl(Q(v) + (v − Q(v))) == v

bit-for-bit in float32.  Why: ``Q(v)`` always lands within a factor of two
of ``v`` (bf16 keeps f32's exponent with a <2⁻⁸ relative error; the int8
dequant ``q·s`` with ``|q| ≥ 1`` sits within ``s/2`` of ``v ≥ s/2``), or is
exactly zero — either way Sterbenz's lemma makes the f32 subtraction exact,
so no mass is ever lost between the wire value and the residual
(tests/test_fused_round.py holds both methods to bitwise equality).

This module is deliberately dependency-free (pure jnp): the Pallas kernel
body, ``kernels.ref`` oracles, and ``core.compression`` all import the same
function, so the three lowerings cannot drift on rounding behavior.
"""
from __future__ import annotations

import jax.numpy as jnp

QUANT_METHODS = ("bf16", "int8")


def quantize_dequant(v, method: str):
    """f32 array -> its deterministic quantize-dequantize image (f32).

    * ``"bf16"`` — round-trip through bfloat16 (8-bit mantissa truncation;
      values beyond the bf16 subnormal range snap to 0, which keeps the
      residual exact — the residual is then ``v`` itself).
    * ``"int8"`` — symmetric per-row linear quantization over the **last
      axis**: scale ``s = max|v|/127`` per row, ``q = round(v/s)`` clipped
      to ±127, dequant ``q·s``.  An all-zero row has ``s = 0`` and maps to
      exact zeros.  Rows are clients in the packed ``(n, D)`` layout, so
      each client's wire scale is its own — one f32 scale + D int8 codes
      per client per round on a real wire.
    """
    if method == "bf16":
        return v.astype(jnp.bfloat16).astype(jnp.float32)
    if method == "int8":
        s = jnp.max(jnp.abs(v), axis=-1, keepdims=True) * jnp.float32(1 / 127)
        safe = jnp.where(s > 0, s, jnp.float32(1.0))
        q = jnp.clip(jnp.round(v / safe), -127.0, 127.0)
        return jnp.where(s > 0, q * safe, jnp.float32(0.0))
    raise ValueError(f"unknown quantize method {method!r}: {QUANT_METHODS}")


def wire_bits(method: str) -> int:
    """Payload bits per element on the wire (the compression claim the
    bench reports): bf16 = 16, int8 = 8 (+ one f32 scale per row)."""
    if method == "bf16":
        return 16
    if method == "int8":
        return 8
    raise ValueError(f"unknown quantize method {method!r}: {QUANT_METHODS}")
