"""Pure-jnp oracles for every Pallas kernel (token-by-token recurrences and
naive attention) — the ground truth the kernels are allclose-tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0, scale=None):
    """q: (BH, Sq, D); k, v: (BKV, Sk, D); GQA by head-group replication."""
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    scale = (d ** -0.5) if scale is None else scale
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= kj > qi - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(xdt, loga, bm, cm):
    """Token-by-token SSD recurrence.  xdt: (BH,S,P); loga: (BH,S);
    bm, cm: (B,S,N).  Returns y: (BH,S,P)."""
    bh, s, p = xdt.shape
    b, _, n = bm.shape
    heads = bh // b
    bmr = jnp.repeat(bm, heads, axis=0)
    cmr = jnp.repeat(cm, heads, axis=0)

    def step(state, inp):
        x_t, la_t, b_t, c_t = inp
        state = jnp.exp(la_t)[:, None, None] * state + jnp.einsum(
            "bp,bn->bpn", x_t.astype(jnp.float32), b_t.astype(jnp.float32))
        y_t = jnp.einsum("bn,bpn->bp", c_t.astype(jnp.float32), state)
        return state, y_t

    state0 = jnp.zeros((bh, p, n), jnp.float32)
    xs = (xdt.swapaxes(0, 1), loga.swapaxes(0, 1),
          bmr.swapaxes(0, 1), cmr.swapaxes(0, 1))
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(xdt.dtype)


def fused_ce_ref(hidden, weight, labels):
    """Plain CE oracle: logits = hidden @ weight.T; NLL per token."""
    logits = hidden.astype(jnp.float32) @ weight.astype(jnp.float32).T
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]


def fused_gossip_ref(w, delta, theta, c, eta_s, corr_scale, *,
                     gossip_dtype=None):
    """Packed round-epilogue oracle (Algorithm 1 lines 7–11 for one variable).

    w: (n, n); delta/theta/c: (n, D) f32.  Mirrors ``mixing.mix_dense``'s
    dtype rules: the matmul operands are narrowed to ``gossip_dtype`` (the
    communicated values), accumulation is f32, and Δ stays f32 inside the
    correction.  Returns (θ_new, c_new) = (Wθ + η_s·WΔ, c + s·(Δ − WΔ)).
    """
    w = jnp.asarray(w, jnp.float32)
    d32 = delta.astype(jnp.float32)
    t32 = theta.astype(jnp.float32)
    if gossip_dtype is None:
        wg, dg, tg = w, d32, t32
    else:
        wg = w.astype(gossip_dtype)
        dg = d32.astype(gossip_dtype)
        tg = t32.astype(gossip_dtype)
    wd = jnp.einsum("ij,jd->id", wg, dg, preferred_element_type=jnp.float32)
    wt = jnp.einsum("ij,jd->id", wg, tg, preferred_element_type=jnp.float32)
    theta_new = wt + eta_s * wd
    c_new = c.astype(jnp.float32) + corr_scale * (d32 - wd)
    return theta_new, c_new


def fused_round_ref(w, z0, c, ef, g, h_steps, step, etas, corr, mask, *,
                    compress=None, gossip_dtype=None):
    """Whole-round oracle (K affine local SGDA steps + gossip epilogue) —
    the ground truth for ``kernels/fused_round.py``.

    w: (n, n); z0/c/ef/step/etas/corr/mask: (n, dz) f32; g: (n, dz, dz);
    h_steps: (K, n, dz).  Semantics documented in the kernel module; the
    quantizer is the shared ``kernels.quantize.quantize_dequant`` so the
    lowerings cannot drift on rounding.  Returns (z_new, c_new, ef_new).
    """
    from repro.kernels.quantize import quantize_dequant

    z0 = z0.astype(jnp.float32)
    c32 = c.astype(jnp.float32)

    def body(z, h):
        grad = jnp.einsum("nij,nj->ni", g, z,
                          preferred_element_type=jnp.float32)
        return z - step * (grad + h + c32), None

    zk, _ = jax.lax.scan(body, z0, h_steps)
    delta = zk - z0
    ef32 = ef.astype(jnp.float32)
    if compress is None:
        q, e_new = delta, ef32
    else:
        v = mask * (delta + ef32)
        q = quantize_dequant(v, compress)
        e_new = jnp.where(mask > 0, v - q, ef32)
    w32 = jnp.asarray(w, jnp.float32)
    if gossip_dtype is None:
        wg, qg, zg = w32, q, z0
    else:
        wg = w32.astype(gossip_dtype)
        qg = q.astype(gossip_dtype)
        zg = z0.astype(gossip_dtype)
    wq = jnp.einsum("ij,jd->id", wg, qg, preferred_element_type=jnp.float32)
    wz = jnp.einsum("ij,jd->id", wg, zg, preferred_element_type=jnp.float32)
    return wz + etas * wq, c32 + corr * (q - wq), e_new


def sparse_gossip_ref(neighbor_idx, neighbor_w, self_w, delta, theta, c,
                      eta_s, corr_scale, *, gossip_dtype=None):
    """Sparse (neighbor-list) round-epilogue oracle — same epilogue as
    ``fused_gossip_ref`` with W given in padded-CSR form.

    neighbor_idx: (n, m) int32 (padding = own index); neighbor_w: (n, m)
    with padding weight 0; self_w: (n,) diagonal; delta/theta/c: (n, D).
    Raw arrays (not a ``SparseTopology``) so the kernels package stays free
    of core imports.  Mirrors the dense oracle's dtype rules: weights and
    communicated values narrow to ``gossip_dtype``, products accumulate in
    f32, Δ stays f32 inside the correction.
    """
    d32 = delta.astype(jnp.float32)
    t32 = theta.astype(jnp.float32)
    if gossip_dtype is None:
        dg, tg = d32, t32
        nwg = neighbor_w.astype(jnp.float32)
        swg = self_w.astype(jnp.float32)
    else:
        dg = d32.astype(gossip_dtype)
        tg = t32.astype(gossip_dtype)
        nwg = neighbor_w.astype(gossip_dtype)
        swg = self_w.astype(gossip_dtype)

    def spmv(x):
        gathered = jnp.take(x, neighbor_idx, axis=0)        # (n, m, D)
        return (swg.astype(jnp.float32)[:, None] * x.astype(jnp.float32)
                + jnp.einsum("nm,nmd->nd", nwg, gathered,
                             preferred_element_type=jnp.float32))

    wd = spmv(dg)
    theta_new = spmv(tg) + eta_s * wd
    c_new = c.astype(jnp.float32) + corr_scale * (d32 - wd)
    return theta_new, c_new


def robust_agg_ref(vals, valid, *, rule, trim: int = 1):
    """Robust-aggregation oracle (coordinate median / b-trimmed mean over
    each row's valid slots) — the ground truth ``mixing.robust_mix_dense``
    and ``robust_mix_sparse`` are parity-tested against.

    vals: (n, m, D) candidate values; valid: (n, m) bool with ≥ 1 valid
    slot per row.  Non-finite values are invalid per coordinate (a diverged
    attacker must not consume a trim slot — ``mixing._robust_reduce``'s
    contract).  Deliberately a *different* float path from the
    implementations: the median goes through ``jnp.nanmedian`` and the
    trimmed mean sorts **descending** (so the surviving values accumulate
    in the reverse order), which makes agreement a real check rather than
    the same expression twice.
    """
    v32 = vals.astype(jnp.float32)
    ok = valid[:, :, None] & jnp.isfinite(v32)
    if rule == "coord_median":
        return jnp.nanmedian(jnp.where(ok, v32, jnp.nan), axis=1)
    if rule != "trimmed_mean":
        raise ValueError(f"unknown robust rule {rule!r}")
    n, m, d = vals.shape
    k = ok.sum(1).astype(jnp.int32)                          # (n, D)
    b = jnp.minimum(jnp.int32(trim), (k - 1) // 2)
    # invalid -> -inf, ascending sort, reverse: valid descending, pad last
    desc = jnp.sort(jnp.where(ok, v32, -jnp.inf), axis=1)[:, ::-1]
    rank = jnp.arange(m, dtype=jnp.int32)[None, :, None]
    keep = (rank >= b[:, None, :]) & (rank < (k - b)[:, None, :])
    total = jnp.sum(jnp.where(keep, desc, 0.0), axis=1)
    return total / (k - 2 * b).astype(jnp.float32)


def rglru_ref(a, u):
    """Token-by-token h_t = a_t h_{t-1} + u_t.  a, u: (B,S,W)."""

    def step(h, inp):
        a_t, u_t = inp
        h = a_t * h + u_t
        return h, h

    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(
        step, h0, (a.swapaxes(0, 1).astype(jnp.float32),
                   u.swapaxes(0, 1).astype(jnp.float32)))
    return hs.swapaxes(0, 1).astype(a.dtype)
