"""RG-LRU linear-recurrence Pallas kernel (TPU target, interpret-validated).

h_t = a_t * h_{t-1} + u_t over (B, S, W), chunked: grid (B, n_chunks) with the
carry h (W,) in VMEM scratch; within a chunk a log-depth Blelloch-style
doubling scan over the (L, W) tile (vector ops on W lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, u_ref, h_ref, h_scr, *, length):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)  # (L, W)
    u = u_ref[0].astype(jnp.float32)  # (L, W)
    # fold carry into the first element
    u = u.at[0].add(a[0] * h_scr[...])

    # inclusive scan of the affine maps (a, u) by doubling:
    # (a, u)_t <- (a_t * a_{t-s}, a_t * u_{t-s} + u_t) for s = 1,2,4,...
    s = 1
    while s < length:
        a_sh = jnp.pad(a, ((s, 0), (0, 0)), constant_values=1.0)[:length]
        u_sh = jnp.pad(u, ((s, 0), (0, 0)))[:length]
        u = a * u_sh + u
        a = a * a_sh
        s *= 2

    h_ref[0] = u.astype(h_ref.dtype)  # u now holds h_t
    h_scr[...] = u[-1]


def rglru_scan_b(a, u, *, chunk: int = 256, interpret: bool = True):
    """a, u: (B, S, W); returns h: (B, S, W).  S must divide by chunk."""
    b, s, w = a.shape
    l = min(chunk, s)
    nc = s // l
    grid = (b, nc)
    kernel = functools.partial(_kernel, length=l)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, l, w), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, l, w), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, w), a.dtype),
        scratch_shapes=[pltpu.VMEM((w,), jnp.float32)],
        interpret=interpret,
    )(a, u)
