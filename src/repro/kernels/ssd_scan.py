"""Mamba2 SSD chunked-scan Pallas kernel (TPU target, interpret-validated).

Grid: (batch*heads, n_chunks) — TPU iterates chunks sequentially per (b,h),
so the inter-chunk SSM state (P, N) lives in VMEM scratch.  Each step does
the intra-chunk dual (matmul) form on an (L, P) x (L, N) tile:

    cum_t   = cumsum(loga)                       (L,)
    scores  = exp(cum_t - cum_u) (C_t.B_u) [u<=t] (L, L)
    y       = scores @ xdt + exp(cum) * (C @ state^T)
    state   = exp(cum_L) state + ((exp(cum_L - cum) * xdt)^T @ B)

VMEM per step: L*(P+2N) inputs + (P,N) state + (L,L) scores — with L=64,
P=64, N=128 well under the ~16 MB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xdt_ref, loga_ref, b_ref, c_ref, y_ref, state_scr, *, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0].astype(jnp.float32)    # (L, P)
    loga = loga_ref[0].astype(jnp.float32)  # (L,)
    bm = b_ref[0].astype(jnp.float32)       # (L, N)
    cm = c_ref[0].astype(jnp.float32)       # (L, N)
    state = state_scr[...]                  # (P, N)

    cum = jnp.cumsum(loga)                  # (L,) inclusive
    rel = cum[:, None] - cum[None, :]       # (L, L)
    l = xdt.shape[0]
    tri = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (l, l), 1)
    decay = jnp.where(tri, jnp.exp(rel), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    scores = decay * cb
    y_intra = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (L, P)
    # inter-chunk: y_inter[t] = exp(cum_t) * C_t . state  -> (L, P)
    c_state = jax.lax.dot_general(cm, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (L, P)
    y = y_intra + jnp.exp(cum)[:, None] * c_state
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S <- exp(cum_L) S + sum_u exp(cum_L - cum_u) xdt_u (x) B_u
    dec_end = jnp.exp(cum[-1] - cum)        # (L,)
    xw = xdt * dec_end[:, None]             # (L, P)
    s_chunk = jax.lax.dot_general(xw, bm, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (P, N)
    state_scr[...] = jnp.exp(cum[-1]) * state + s_chunk


def ssd_scan_bh(xdt, loga, bm, cm, *, chunk: int = 64, interpret: bool = True):
    """xdt: (BH, S, P); loga: (BH, S); bm, cm: (B, S, N) broadcast per head
    via index maps (heads of one batch share B/C).  S must divide by chunk.
    Returns y: (BH, S, P) plus NO final state (training path)."""
    bh, s, p = xdt.shape
    b = bm.shape[0]
    assert bh % b == 0
    heads = bh // b
    l = min(chunk, s)
    nc = s // l
    grid = (bh, nc)

    kernel = functools.partial(_kernel, chunk=l)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, l), lambda i, j: (i, j)),
            pl.BlockSpec((1, l, bm.shape[-1]), lambda i, j, h=heads: (i // h, j, 0)),
            pl.BlockSpec((1, l, cm.shape[-1]), lambda i, j, h=heads: (i // h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, l, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((p, bm.shape[-1]), jnp.float32)],
        interpret=interpret,
    )(xdt, loga, bm, cm)
