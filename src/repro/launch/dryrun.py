import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init).  512 placeholder host devices back both the
# single-pod (256-chip) and 2-pod (512-chip) production meshes.

"""Multi-pod dry-run: AOT-lower + compile every (architecture x input shape)
on the production mesh(es), and extract the roofline raw terms.

  train_4k            -> one full K-GT-Minimax round on the decentralized mesh
  prefill_32k         -> batched prefill on the serving mesh
  decode_32k/long_500k-> one-token decode against a seq_len cache

Per entry we record memory_analysis (proves it fits), cost_analysis (FLOPs /
bytes for the roofline), and per-collective byte totals parsed from the
compiled HLO.  Results append to a JSONL (skip-if-done), so the full 40x2
matrix can be built up incrementally.

Usage:
  python -m repro.launch.dryrun --archs qwen2-0.5b --shapes train_4k --meshes single
  python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp

from repro.analysis import hlo_cost
from repro.configs import registry
from repro.configs.shapes import SHAPES
from repro.dist import compat
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib


def _fmt_bytes(b):
    return f"{b/2**30:.2f}GiB"


# Named perf variants (§Perf hillclimb).  "baseline" is the paper-faithful
# configuration: dense-W fp32 gossip, two exchanges/variable, FSDP-2D params.
import dataclasses as _dc

from repro.configs.base import AlgorithmConfig as _Algo

VARIANTS = {
    "baseline": dict(),
    # label-only variants: same config, used to snapshot code-level changes
    "grouped_gqa": dict(),
    "final": dict(algo=dict(mixing_impl="fused_ring", gossip_dtype="bfloat16")),
    "bf16_gossip": dict(algo=dict(gossip_dtype="bfloat16")),
    "ring": dict(algo=dict(mixing_impl="ring")),
    "fused_ring_bf16": dict(
        algo=dict(mixing_impl="fused_ring", gossip_dtype="bfloat16")),
    "replicated": dict(mesh=dict(param_mode="replicated")),
    "replicated_fused": dict(
        algo=dict(mixing_impl="fused_ring", gossip_dtype="bfloat16"),
        mesh=dict(param_mode="replicated")),
    "expert_parallel": dict(mesh=dict(moe_expert_parallel=True)),
    "ep_fused": dict(
        algo=dict(mixing_impl="fused_ring", gossip_dtype="bfloat16"),
        mesh=dict(moe_expert_parallel=True)),
    "no_remat": dict(mesh=dict(remat=False)),
    "attn_heads": dict(mesh=dict(attn_heads_sharding=True)),
    "batch_residual": dict(mesh=dict(residual_mode="batch")),
    "ep_batch_residual": dict(
        algo=dict(mixing_impl="fused_ring", gossip_dtype="bfloat16"),
        mesh=dict(moe_expert_parallel=True, residual_mode="batch")),
    "attn_heads_fused": dict(
        algo=dict(mixing_impl="fused_ring", gossip_dtype="bfloat16"),
        mesh=dict(attn_heads_sharding=True)),
    # recommended per-arch optimized config: grouped-GQA is code-level (always
    # on); MoE additionally wants expert-parallel.  attn_heads/fused_ring were
    # measured regressions on several archs (see EXPERIMENTS.md §Perf).
    "best": dict(mesh=dict(moe_expert_parallel=True)),
    "moe_sorted": dict(moe=dict(dispatch="sorted")),
    "moe_sorted_ep": dict(moe=dict(dispatch="sorted"),
                          mesh=dict(moe_expert_parallel=True)),
}


def run_pair(arch_id: str, shape_name: str, mesh_kind: str,
             variant: str = "baseline") -> Dict:
    multi = mesh_kind == "multi"
    shape = SHAPES[shape_name]
    cfg = registry.get_model_config(arch_id)
    rec = dict(arch=arch_id, shape=shape_name, mesh=mesh_kind, variant=variant)
    over = VARIANTS[variant]
    if over.get("moe") and cfg.moe.num_experts:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, **over["moe"]))
    # monotonic clock for the compile interval (NTP slew can make wall-clock
    # deltas negative); REPRO_COMPILE_CACHE arms jax's
    # persistent compilation cache so repeated dry-runs skip the backend
    # compile (the AOT layer doesn't apply: dry-runs never execute)
    from repro.sweep import cache as cache_lib

    cache_lib.from_env()
    t0 = time.perf_counter()

    if shape.kind == "train":
        mcfg = mesh_lib.decentralized_mesh_config(arch_id, multi_pod=multi)
        if over.get("mesh"):
            mcfg = _dc.replace(mcfg, **over["mesh"])
        algo = _Algo(num_clients=mcfg.num_clients, **over.get("algo", {}))
        mesh = mesh_lib.make_decentralized_mesh(mcfg)
        rec["mesh_shape"] = dict(zip(mesh.axis_names, mesh.devices.shape))
        with compat.use_mesh(mesh):
            jitted, state_sds, batch_sds, key_sds, _ = steps_lib.build_train_round(
                cfg, shape, mesh, mcfg, algo=algo)
            lowered = jitted.lower(state_sds, batch_sds, key_sds)
            compiled = lowered.compile()
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi)
        rec["mesh_shape"] = dict(zip(mesh.axis_names, mesh.devices.shape))
        mcfg_model = cfg
        if shape.name == "long_500k":
            mcfg_model = steps_lib.long_context_variant(cfg)
            rec["variant"] = (
                "native-subquadratic" if mcfg_model is cfg else "sliding-window-4096")
        with compat.use_mesh(mesh):
            if shape.kind == "prefill":
                jitted, p_sds, b_sds, c_sds = steps_lib.build_prefill_step(
                    mcfg_model, shape, mesh)
                lowered = jitted.lower(p_sds, b_sds, c_sds)
            else:
                jitted, p_sds, c_sds, t_sds, pos_sds = steps_lib.build_decode_step(
                    mcfg_model, shape, mesh)
                lowered = jitted.lower(p_sds, c_sds, t_sds, pos_sds)
            compiled = lowered.compile()

    rec["compile_s"] = round(time.perf_counter() - t0, 3)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
    }
    rec["memory"]["peak_per_device"] = (
        rec["memory"]["argument_bytes"] + rec["memory"]["output_bytes"]
        + rec["memory"]["temp_bytes"] - rec["memory"]["alias_bytes"])

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    rec["cost_xla"] = {  # XLA's own numbers (counts while bodies once)
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }
    # loop-aware parsed costs (per device) — the roofline source of truth
    summary = hlo_cost.analyze(compiled.as_text())
    rec["cost"] = {
        "dot_flops": summary.dot_flops,
        "traffic_bytes": summary.traffic_bytes,
        "transcendental_elems": summary.transcendental_elems,
    }
    rec["collectives"] = {
        **{k: float(v) for k, v in summary.collective_bytes.items()},
        **{f"n_{k}": float(v) for k, v in summary.collective_counts.items()},
    }
    print(
        f"[dryrun] {arch_id} x {shape_name} x {mesh_kind} [{variant}]: "
        f"compile {rec['compile_s']}s  "
        f"peak/device {_fmt_bytes(rec['memory']['peak_per_device'])}  "
        f"TFLOPs/dev {summary.dot_flops/1e12:.2f}  "
        f"coll {summary.total_collective_bytes()/2**30:.3f}GiB",
        flush=True,
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="*", default=None)
    ap.add_argument("--shapes", nargs="*", default=None)
    ap.add_argument("--meshes", nargs="*", default=["single", "multi"])
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="/root/repo/results/dryrun.jsonl")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = args.archs or list(registry.ASSIGNED)
    shapes = args.shapes or list(SHAPES)
    meshes = args.meshes

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    done = set()
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if "error" not in r:
                        done.add((r["arch"], r["shape"], r["mesh"],
                                  r.get("variant", "baseline")))
                except json.JSONDecodeError:
                    pass

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                if (arch, shape, mesh_kind, args.variant) in done:
                    print(f"[dryrun] skip done {arch} x {shape} x {mesh_kind}")
                    continue
                try:
                    rec = run_pair(arch, shape, mesh_kind, args.variant)
                except Exception as e:  # record and continue
                    rec = dict(arch=arch, shape=shape, mesh=mesh_kind,
                               variant=args.variant,
                               error=f"{type(e).__name__}: {e}",
                               trace=traceback.format_exc()[-2000:])
                    print(f"[dryrun] FAIL {arch} x {shape} x {mesh_kind}: {rec['error']}",
                          flush=True)
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
