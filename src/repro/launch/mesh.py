"""Production and decentralized meshes.

``make_production_mesh`` is the launch-spec mesh (verbatim).  The
decentralized *logical* mesh reshapes the same device array to
("clients", "fsdp", "model"): one K-GT-Minimax client per contiguous block of
fsdp x model chips.  In the multi-pod mesh the clients axis spans the pod
boundary, so only the gossip exchange (once per K local steps — the paper's
entire point) crosses inter-pod links.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import MeshConfig
from repro.dist import compat
from repro.dist.sharding import CLIENTS, FSDP, MODEL


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_decentralized_mesh(mcfg: MeshConfig) -> Mesh:
    """Reshape the production device array to (clients, fsdp, model)."""
    prod = make_production_mesh(multi_pod=mcfg.multi_pod)
    devices = prod.devices.reshape(mcfg.num_clients, mcfg.fsdp, mcfg.model)
    return compat.mesh_of(devices, (CLIENTS, FSDP, MODEL))


# Per-arch overrides of the decentralized layout: the 70B-class model needs a
# bigger per-client sub-mesh to fit fp32 tracking state in 16 GB HBM.
_ARCH_MESH = {
    "internvl2-76b": dict(num_clients=2, fsdp=8),
    "qwen1.5-32b": dict(num_clients=4, fsdp=4),
}


def decentralized_mesh_config(arch_id: str, *, multi_pod: bool = False) -> MeshConfig:
    kw = dict(_ARCH_MESH.get(arch_id, dict(num_clients=4, fsdp=4)))
    kw["model"] = 16
    if multi_pod:
        kw["num_clients"] *= 2  # clients axis spans the pod dimension
    return MeshConfig(multi_pod=multi_pod, **kw)


def local_mesh(n_devices: Optional[int] = None) -> Mesh:
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    devs = np.array(jax.devices()[: n_devices or len(jax.devices())])
    return compat.mesh_of(devs.reshape(len(devs), 1, 1), (CLIENTS, FSDP, MODEL))


def fake_mesh(num_clients: int = 2, fsdp: int = 2, model: int = 2) -> Mesh:
    """CPU-backed fake decentralized mesh for compile-level tests.

    Requires ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (with
    N >= num_clients*fsdp*model) to be set before jax's first backend init —
    see ``repro.launch.smoke`` / ``scripts/smoke.sh``.
    """
    need = num_clients * fsdp * model
    if len(jax.devices()) < need:
        raise RuntimeError(
            f"fake_mesh needs {need} devices, have {len(jax.devices())}; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before jax init")
    return compat.make_mesh((num_clients, fsdp, model), (CLIENTS, FSDP, MODEL))
