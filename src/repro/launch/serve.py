"""Serving driver: batched prefill + decode on the production mesh.

On real hardware this binds the AOT-compiled steps from
``repro.launch.steps`` to live buffers; on this CPU container use
``--local`` for a single-device demo on a reduced config (the multi-chip
path is exercised AOT by ``repro.launch.dryrun``).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --local
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import InputShape
from repro.configs.shapes import SHAPES


def serve_local(arch: str, batch: int, prompt_len: int, gen_tokens: int,
                temperature: float) -> None:
    from repro.models import decode_step, init_cache, init_params

    cfg = registry.reduced(registry.get_model_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    total = prompt_len + gen_tokens
    shape = ((batch, prompt_len, cfg.num_codebooks) if cfg.num_codebooks
             else (batch, prompt_len))
    prompt = jax.random.randint(key, shape, 0, cfg.vocab_size)
    caches = init_cache(cfg, batch, total)

    step = jax.jit(
        lambda p, c, t, pos: decode_step(p, c, t, pos, cfg),
        donate_argnums=(1,))
    logits = None
    t0 = time.time()
    for t in range(prompt_len):
        logits, caches = step(params, caches, prompt[:, t:t+1], jnp.int32(t))
    print(f"[serve] prefill {prompt_len} tok x {batch} seq: {time.time()-t0:.2f}s")
    t0 = time.time()
    for i in range(gen_tokens):
        key, ks = jax.random.split(key)
        tok = jax.random.categorical(
            ks, logits[:, -1].astype(jnp.float32) / temperature, axis=-1)
        tok = tok[:, None] if not cfg.num_codebooks else tok[:, None, :]
        logits, caches = step(params, caches, tok, jnp.int32(prompt_len + i))
    dt = time.time() - t0
    print(f"[serve] decoded {gen_tokens} tok/seq in {dt:.2f}s "
          f"({gen_tokens * batch / dt:.1f} tok/s aggregate)")


def serve_production(arch: str, shape_name: str, multi_pod: bool) -> None:
    """AOT-compile the serving steps against the production mesh and report
    the binding points (a real deployment feeds live params/caches here)."""
    from repro.dist import compat
    from repro.launch import mesh as mesh_lib
    from repro.launch import steps as steps_lib

    cfg = registry.get_model_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    with compat.use_mesh(mesh):
        if shape.kind == "prefill":
            jitted, p_sds, b_sds, c_sds = steps_lib.build_prefill_step(
                cfg, shape, mesh)
            compiled = jitted.lower(p_sds, b_sds, c_sds).compile()
        else:
            cfg2 = steps_lib.long_context_variant(cfg) \
                if shape.name == "long_500k" else cfg
            jitted, p_sds, c_sds, t_sds, pos_sds = steps_lib.build_decode_step(
                cfg2, shape, mesh)
            compiled = jitted.lower(p_sds, c_sds, t_sds, pos_sds).compile()
    mem = compiled.memory_analysis()
    print(f"[serve] {arch} x {shape_name} compiled for "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}; "
          f"peak/device ≈ {(mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes - mem.alias_size_in_bytes)/2**30:.2f} GiB")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--shape", default="decode_32k",
                    choices=[s for s in SHAPES if s != "train_4k"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()
    if args.local:
        serve_local(args.arch, args.batch, args.prompt_len, args.tokens,
                    args.temperature)
    else:
        serve_production(args.arch, args.shape, args.multi_pod)


if __name__ == "__main__":
    main()
