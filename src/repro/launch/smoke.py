import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# NOTE: must run before any other import — jax locks the device count at
# first backend init.  8 fake host devices back a (2,2,2) decentralized mesh
# and a (4,2) serving mesh.

"""Compile-level smoke of the whole launch stack on CPU fake devices.

For a reduced architecture, builds and jit-compiles all three step programs
against their meshes:

  train  -> one K-GT-Minimax round on a (clients=2, fsdp=2, model=2) mesh
  prefill/decode -> the serving steps on a (data=4, model=2) mesh

This is the fastest end-to-end check that ``repro.dist`` shardings, the
residual-constraint context, and the model stack agree — and the second leg
of ``scripts/smoke.sh`` (the future CI entrypoint).  Exit code 0 iff every
build compiles.

Usage:
  PYTHONPATH=src python -m repro.launch.smoke [--archs qwen2-0.5b ...]
"""
import argparse
import dataclasses
import sys
import time

import jax

from repro.configs import registry
from repro.configs.base import AlgorithmConfig, InputShape, MeshConfig
from repro.dist import compat
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib

TRAIN_SHAPE = InputShape(name="smoke_train", seq_len=64, global_batch=4,
                         kind="train")
SERVE_SHAPE = InputShape(name="smoke_serve", seq_len=64, global_batch=8,
                         kind="prefill")


def smoke_arch(arch: str) -> bool:
    cfg = registry.reduced(registry.get_model_config(arch))
    ok = True

    t0 = time.time()
    mesh = mesh_lib.fake_mesh(2, 2, 2)
    mcfg = MeshConfig(num_clients=2, fsdp=2, model=2,
                      moe_expert_parallel=bool(cfg.moe.num_experts))
    algo = AlgorithmConfig(num_clients=2, local_steps=2)
    try:
        with compat.use_mesh(mesh):
            jitted, state_sds, batch_sds, key_sds, _ = steps_lib.build_train_round(
                cfg, TRAIN_SHAPE, mesh, mcfg, algo=algo)
            jitted.lower(state_sds, batch_sds, key_sds).compile()
        print(f"[smoke] {arch}: train round compiled "
              f"({time.time()-t0:.1f}s)", flush=True)
    except Exception as e:
        ok = False
        print(f"[smoke] {arch}: train FAILED: {type(e).__name__}: {e}",
              flush=True)

    # the packed fused-gossip round must also lower under GSPMD (one
    # collective per variable instead of one per leaf — see
    # repro.core.packing / repro.kernels.gossip)
    t0 = time.time()
    packed_algo = dataclasses.replace(algo, mixing_impl="pallas_packed")
    try:
        with compat.use_mesh(mesh):
            jitted, state_sds, batch_sds, key_sds, _ = steps_lib.build_train_round(
                cfg, TRAIN_SHAPE, mesh, mcfg, algo=packed_algo)
            jitted.lower(state_sds, batch_sds, key_sds).compile()
        print(f"[smoke] {arch}: packed-gossip train round compiled "
              f"({time.time()-t0:.1f}s)", flush=True)
    except Exception as e:
        ok = False
        print(f"[smoke] {arch}: packed train FAILED: {type(e).__name__}: {e}",
              flush=True)

    # the sparse neighbor-gather round must also lower under GSPMD — same
    # fused epilogue with W as padded-CSR neighbor lists instead of an
    # (n, n) matrix (repro.core.sparse_topology / kernels.neighbor_gossip)
    t0 = time.time()
    sparse_algo = dataclasses.replace(algo, mixing_impl="sparse_packed")
    try:
        with compat.use_mesh(mesh):
            jitted, state_sds, batch_sds, key_sds, _ = steps_lib.build_train_round(
                cfg, TRAIN_SHAPE, mesh, mcfg, algo=sparse_algo)
            jitted.lower(state_sds, batch_sds, key_sds).compile()
        print(f"[smoke] {arch}: sparse-gossip train round compiled "
              f"({time.time()-t0:.1f}s)", flush=True)
    except Exception as e:
        ok = False
        print(f"[smoke] {arch}: sparse train FAILED: {type(e).__name__}: {e}",
              flush=True)

    # the scanned engine chunk (repro.engine execution model): R rounds as
    # one program with device-side sampling + metrics buffer, donated
    # sharded state — the hot path of launch/train --engine scan on a mesh
    t0 = time.time()
    try:
        import jax.numpy as jnp

        from repro import engine as engine_lib
        from repro.configs.base import MinimaxConfig
        from repro.core import objectives
        from repro.data import synthetic as data_lib

        key = jax.random.PRNGKey(0)
        dm = data_lib.make_data_model(
            key, vocab_size=cfg.vocab_size, num_groups=4,
            num_clients=algo.num_clients)
        sampler = engine_lib.make_dro_sampler(
            dm, key, local_steps=algo.local_steps,
            num_clients=algo.num_clients,
            per_client_batch=TRAIN_SHAPE.global_batch // algo.num_clients,
            seq_len=TRAIN_SHAPE.seq_len, cfg=cfg)
        problem = objectives.dro_problem(cfg, num_groups=4, mu=1.0)
        eval_b = engine_lib.held_out_eval_batch(
            dm, key, num_clients=algo.num_clients,
            per_client_batch=TRAIN_SHAPE.global_batch // algo.num_clients,
            seq_len=TRAIN_SHAPE.seq_len, cfg=cfg)
        metrics_fn = engine_lib.dro_metrics_fn(
            problem, cfg, num_groups=4, eval_batch=eval_b)
        with compat.use_mesh(mesh):
            build_chunk, state_sds, _ = steps_lib.build_train_chunk(
                cfg, TRAIN_SHAPE, mesh, mcfg, algo=algo,
                minimax=MinimaxConfig(num_groups=4),
                sampler=sampler, metrics_fn=metrics_fn, log_every=2)
            build_chunk(4).lower(
                state_sds, jax.ShapeDtypeStruct((), jnp.int32)).compile()
        print(f"[smoke] {arch}: engine chunk (scan x4 rounds) compiled "
              f"({time.time()-t0:.1f}s)", flush=True)
    except Exception as e:
        ok = False
        print(f"[smoke] {arch}: engine chunk FAILED: {type(e).__name__}: {e}",
              flush=True)

    # the vmapped sweep cell (repro.sweep): a stacked trajectory batch as
    # one scanned program with the batch axis GSPMD-sharded over 'clients'
    # of the decentralized mesh — the batch-parallel layout sweeps use
    t0 = time.time()
    try:
        import jax.numpy as jnp

        from repro.sweep import batched as sweep_batched
        from repro.sweep import run as sweep_run

        p = dict(sweep_run.DEFAULT_POINT, n=4, K=2, max_rounds=8,
                 eval_every=4)
        prepared = [sweep_run.prepare_trajectory(dict(p, seed=s))
                    for s in range(4)]
        trajs = sweep_batched.tree_stack([tr for tr, _ in prepared])
        build, _ = sweep_run._cell_programs(p, batched=True, mesh=mesh)
        build(4).lower(trajs, jnp.int32(7)).compile()
        print(f"[smoke] {arch}: sweep cell (vmap x4 trajs, sharded batch "
              f"axis) compiled ({time.time()-t0:.1f}s)", flush=True)
    except Exception as e:
        ok = False
        print(f"[smoke] {arch}: sweep cell FAILED: {type(e).__name__}: {e}",
              flush=True)

    t0 = time.time()
    smesh = compat.make_mesh((4, 2), ("data", "model"))
    try:
        with compat.use_mesh(smesh):
            jp, p_sds, b_sds, c_sds = steps_lib.build_prefill_step(
                cfg, SERVE_SHAPE, smesh)
            jp.lower(p_sds, b_sds, c_sds).compile()
            jd, p_sds, c_sds, t_sds, pos_sds = steps_lib.build_decode_step(
                cfg, SERVE_SHAPE, smesh)
            jd.lower(p_sds, c_sds, t_sds, pos_sds).compile()
        print(f"[smoke] {arch}: prefill+decode compiled "
              f"({time.time()-t0:.1f}s)", flush=True)
    except Exception as e:
        ok = False
        print(f"[smoke] {arch}: serve FAILED: {type(e).__name__}: {e}",
              flush=True)
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="*", default=["qwen2-0.5b"],
                    choices=sorted(registry.ARCHS))
    args = ap.parse_args()
    print(f"[smoke] {len(jax.devices())} fake devices "
          f"({jax.devices()[0].platform})", flush=True)
    results = [smoke_arch(a) for a in args.archs]
    sys.exit(0 if all(results) else 1)


if __name__ == "__main__":
    main()
