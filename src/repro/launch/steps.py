"""Step-function builders: the jit-able programs the dry-run lowers and a real
cluster would execute.

* ``build_train_round``  — one full K-GT-Minimax communication round (K local
  DRO-minimax steps + correction + gossip) over the decentralized mesh.
* ``build_prefill_step`` — batched prefill (logits + populated caches) over
  the production/serving mesh.
* ``build_decode_step``  — one-token decode against a seq_len cache.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import AlgorithmConfig, InputShape, MeshConfig, MinimaxConfig, ModelConfig
from repro.core import kgt_minimax as kgt
from repro.core import objectives, topology
from repro.dist import context as dist_ctx
from repro.dist import sharding as sh
from repro.models import model as model_lib


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Training round
# ---------------------------------------------------------------------------

def _train_parts(
    model_cfg: ModelConfig,
    shape: InputShape,
    mesh: Mesh,
    mcfg: MeshConfig,
    algo: Optional[AlgorithmConfig] = None,
    minimax: Optional[MinimaxConfig] = None,
    lr_scale=None,
):
    """Shared setup for the per-round and chunked train programs: the
    constrained round_step callable, abstract state/batch/key specs, and
    their shardings."""
    algo = algo or AlgorithmConfig(num_clients=mcfg.num_clients)
    algo = dataclasses.replace(algo, num_clients=mcfg.num_clients)
    if (algo.mixing_impl in ("pallas_packed", "sparse_packed")
            and algo.gossip_backend == "auto"):
        # Under GSPMD the clients dim is mesh-sharded and pallas_call is not
        # SPMD-partitioned over it; the packed-xla oracle keeps the
        # one-collective-per-variable lowering (gather-based for sparse),
        # which is the win at mesh scale.  The Pallas kernels themselves are
        # the single-chip epilogue path.
        algo = dataclasses.replace(algo, gossip_backend="xla")
    minimax = minimax or MinimaxConfig()
    n, k_steps = algo.num_clients, algo.local_steps
    assert shape.global_batch % n == 0, (shape.global_batch, n)
    b_client = shape.global_batch // n

    problem = objectives.dro_problem(
        model_cfg, num_groups=minimax.num_groups, mu=minimax.mu,
        compute_dtype=jnp.bfloat16, remat=mcfg.remat)
    w = topology.mixing_matrix(algo.topology, n)
    round_fn = kgt.make_round_step(problem, algo, w, lr_scale=lr_scale)

    # ---- abstract state -------------------------------------------------
    x_one = jax.eval_shape(lambda k: model_lib.init_params(model_cfg, k),
                           jax.random.PRNGKey(0))
    rep = lambda t: jax.tree.map(lambda s: _sds((n, *s.shape), s.dtype), t)
    x_sds = rep(x_one)
    y_sds = _sds((n, minimax.num_groups), jnp.float32)
    state_sds = kgt.KGTState(x=x_sds, y=y_sds, cx=x_sds, cy=y_sds,
                             round=_sds((), jnp.int32))

    # ---- abstract inputs -------------------------------------------------
    tok_shape = (k_steps, n, b_client, shape.seq_len)
    if model_cfg.num_codebooks:
        tok_shape = tok_shape + (model_cfg.num_codebooks,)
    batch_sds: Dict[str, Any] = {
        "tokens": _sds(tok_shape, jnp.int32),
        "labels": _sds(tok_shape, jnp.int32),
        "groups": _sds((k_steps, n, b_client, shape.seq_len), jnp.int32),
    }
    if model_cfg.num_prefix_tokens:
        batch_sds["prefix"] = _sds(
            (k_steps, n, b_client, model_cfg.num_prefix_tokens, model_cfg.d_model),
            jnp.float32)
    key_sds = _sds((k_steps, n, 2), jnp.uint32)

    # ---- shardings -------------------------------------------------------
    x_shard = sh.params_shardings(
        x_sds, mesh, leading_clients=True, param_mode=mcfg.param_mode,
        expert_parallel=mcfg.moe_expert_parallel)
    y_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, P(sh.CLIENTS)), y_sds)
    state_shard = kgt.KGTState(
        x=x_shard, y=y_shard, cx=x_shard, cy=y_shard,
        round=NamedSharding(mesh, P()))
    def batch_spec(s):
        parts = [None, sh.CLIENTS, sh.FSDP, sh.MODEL] + [None] * (len(s.shape) - 4)
        return NamedSharding(mesh, P(*parts[: len(s.shape)]))
    batch_shard = jax.tree.map(batch_spec, batch_sds)
    # prefix (K,n,B,P,d): don't shard the P dim over model
    if "prefix" in batch_sds:
        batch_shard["prefix"] = NamedSharding(
            mesh, P(None, sh.CLIENTS, sh.FSDP, None, None))
    key_shard = NamedSharding(mesh, P(None, sh.CLIENTS, None))

    res_axes = sh.residual_axes(mcfg.residual_mode)
    constraint = sh.leading_dims_constraint(mesh, res_axes)
    slots = {}
    if mcfg.attn_heads_sharding:
        # q (B,S,H,D): heads over model (GSPMD: all-to-all from seq-sharded),
        # context back to seq-sharded before out-projection.
        def qkv_fn(x):
            spec = P(sh.FSDP, None, sh.MODEL, None)
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

        def out_fn(x):
            spec = P(sh.FSDP, sh.MODEL, None, None)
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

        slots = {"attn_qkv": qkv_fn, "attn_out": out_fn}

    def round_step(state, batches, keys):
        with dist_ctx.residual_constraint(constraint, **slots):
            return round_fn(state, batches, keys)

    return (round_step, state_sds, batch_sds, key_sds,
            (state_shard, batch_shard, key_shard))


def build_train_round(
    model_cfg: ModelConfig,
    shape: InputShape,
    mesh: Mesh,
    mcfg: MeshConfig,
    algo: Optional[AlgorithmConfig] = None,
    minimax: Optional[MinimaxConfig] = None,
    lr_scale=None,
):
    """Returns (jitted_round_step, state_sds, batch_sds, key_sds, shardings).

    The round state is x=(n, model params), y=(n, G); batches are stacked
    (K, n, B_client, S...).  Residual activations are constrained to
    (fsdp=batch, model=seq) inside each client.
    """
    round_step, state_sds, batch_sds, key_sds, shardings = _train_parts(
        model_cfg, shape, mesh, mcfg, algo=algo, minimax=minimax,
        lr_scale=lr_scale)
    state_shard, batch_shard, key_shard = shardings
    jitted = jax.jit(
        round_step,
        in_shardings=(state_shard, batch_shard, key_shard),
        out_shardings=state_shard,
        donate_argnums=(0,),
    )
    return jitted, state_sds, batch_sds, key_sds, shardings


def build_train_chunk(
    model_cfg: ModelConfig,
    shape: InputShape,
    mesh: Mesh,
    mcfg: MeshConfig,
    *,
    algo: Optional[AlgorithmConfig] = None,
    minimax: Optional[MinimaxConfig] = None,
    lr_scale=None,
    sampler,
    metrics_fn=None,
    log_every: int = 1,
):
    """The scanned multi-round chunk over the decentralized mesh
    (``repro.engine`` execution model under GSPMD).

    Returns ``(build_chunk, state_sds, state_shard)`` where
    ``build_chunk(length)`` is a jitted ``chunk_step(state, final_round)``
    with the sharded state **donated** across chunk calls.  The sampler runs
    inside the scan body; its batches/keys are pinned to the same
    ``(None, clients, fsdp, model)`` layout the per-round program uses, so
    each client's local steps stay confined to its sub-mesh and only gossip
    crosses the clients axis — now once per compiled chunk of R rounds'
    worth of program, not once per dispatch.
    """
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro import engine as engine_lib

    round_step, state_sds, _, _, shardings = _train_parts(
        model_cfg, shape, mesh, mcfg, algo=algo, minimax=minimax,
        lr_scale=lr_scale)
    state_shard, batch_shard, key_shard = shardings

    def sharded_sampler(round_idx):
        batches, keys = sampler(round_idx)
        batches = jax.tree.map(jax.lax.with_sharding_constraint,
                               batches, batch_shard)
        keys = jax.lax.with_sharding_constraint(keys, key_shard)
        return batches, keys

    def jit_fn(chunk_fn):
        # metrics buffer out_sharding stays unspecified (small, replicated)
        return jax.jit(
            chunk_fn,
            in_shardings=(state_shard, NamedSharding(mesh, P())),
            out_shardings=(state_shard, None),
            donate_argnums=(0,),
        )

    build_chunk = engine_lib.make_chunk_builder(
        round_step, sharded_sampler, metrics_fn, log_every=log_every,
        jit_fn=jit_fn)
    return build_chunk, state_sds, state_shard


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def _serve_batch_axes(mesh: Mesh):
    return (("pod", "data") if "pod" in mesh.axis_names else "data",)


def _axis_size(mesh: Mesh, axis) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axis, 1)


def _maybe(axis, size: int, mesh: Mesh):
    """axis if size divides by its mesh extent, else None (e.g. batch=1)."""
    return axis if size % _axis_size(mesh, axis) == 0 else None


def _bf16_sds(tree):
    """Serving params are bf16 (inference)."""
    return jax.tree.map(
        lambda s: _sds(s.shape, jnp.bfloat16)
        if jnp.issubdtype(s.dtype, jnp.floating) else s, tree)


def build_prefill_step(model_cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """prefill(params, batch) -> (logits_last, caches)."""
    params_sds = _bf16_sds(jax.eval_shape(
        lambda k: model_lib.init_params(model_cfg, k), jax.random.PRNGKey(0)))
    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, s, model_cfg.num_codebooks) if model_cfg.num_codebooks else (b, s)
    batch_sds = {"tokens": _sds(tok_shape, jnp.int32)}
    if model_cfg.num_prefix_tokens:
        batch_sds["prefix"] = _sds(
            (b, model_cfg.num_prefix_tokens, model_cfg.d_model), jnp.float32)
    cache_sds = jax.eval_shape(
        lambda: model_lib.init_cache(model_cfg, b, s, jnp.bfloat16))

    batch_axis = _serve_batch_axes(mesh)[0]
    # serving residual: batch over data, seq over model (sequence parallelism;
    # GSPMD gathers seq around attention and re-scatters — measured strictly
    # better than batch-only TP layout here, see EXPERIMENTS.md §Perf).
    constraint = sh.leading_dims_constraint(mesh, (batch_axis, "model"))

    def prefill(params, batch, caches):
        with dist_ctx.residual_constraint(constraint):
            logits, new_caches, _ = model_lib.forward(
                params, batch, model_cfg, mode="prefill",
                compute_dtype=jnp.bfloat16, caches=caches, last_only=True)
        return logits, new_caches

    p_shard = sh.serve_params_shardings(params_sds, mesh)
    c_shard = _cache_shardings(cache_sds, mesh, batch_axis)
    b_shard = jax.tree.map(
        lambda sds: NamedSharding(
            mesh, P(*([_maybe(batch_axis, sds.shape[0], mesh)]
                      + [None] * (len(sds.shape) - 1)))),
        batch_sds)
    jitted = jax.jit(prefill, in_shardings=(p_shard, b_shard, c_shard),
                     out_shardings=None)
    return jitted, params_sds, batch_sds, cache_sds


def _cache_shardings(cache_sds, mesh: Mesh, batch_axis):
    """(reps, B, …) cache leaves: batch over the data axes; the largest
    trailing dim divisible by the model-axis size over 'model'."""
    n_model = _axis_size(mesh, "model")

    def spec(sds):
        shp = sds.shape
        parts = [None] * len(shp)
        if len(shp) >= 2:
            parts[1] = _maybe(batch_axis, shp[1], mesh)
        cands = [(sz, i) for i, sz in enumerate(shp[2:], start=2)
                 if sz % n_model == 0 and sz >= n_model]
        if cands:
            parts[max(cands)[1]] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(spec, cache_sds)


def build_decode_step(model_cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """decode(params, caches, tokens, pos) -> (logits, new_caches)."""
    params_sds = _bf16_sds(jax.eval_shape(
        lambda k: model_lib.init_params(model_cfg, k), jax.random.PRNGKey(0)))
    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, 1, model_cfg.num_codebooks) if model_cfg.num_codebooks else (b, 1)
    tok_sds = _sds(tok_shape, jnp.int32)
    cache_sds = jax.eval_shape(
        lambda: model_lib.init_cache(model_cfg, b, s, jnp.bfloat16))
    pos_sds = _sds((), jnp.int32)

    batch_axis = _serve_batch_axes(mesh)[0]
    constraint = sh.leading_dims_constraint(mesh, (batch_axis,))

    def decode(params, caches, tokens, pos):
        with dist_ctx.residual_constraint(constraint):
            return model_lib.decode_step(params, caches, tokens, pos, model_cfg,
                                         compute_dtype=jnp.bfloat16)

    p_shard = sh.serve_params_shardings(params_sds, mesh)
    c_shard = _cache_shardings(cache_sds, mesh, batch_axis)
    t_shard = NamedSharding(
        mesh, P(*([_maybe(batch_axis, tok_shape[0], mesh)]
                  + [None] * (len(tok_shape) - 1))))
    jitted = jax.jit(
        decode,
        in_shardings=(p_shard, c_shard, t_shard, NamedSharding(mesh, P())),
        out_shardings=None,
        donate_argnums=(1,),
    )
    return jitted, params_sds, cache_sds, tok_sds, pos_sds


# ---------------------------------------------------------------------------
# long_500k config variant
# ---------------------------------------------------------------------------

def long_context_variant(model_cfg: ModelConfig) -> ModelConfig:
    """Sub-quadratic variant for long_500k: SSM/hybrid archs are native;
    full-attention archs get a 4096-token sliding window (beyond-paper,
    flagged in the dry-run table)."""
    if model_cfg.arch_type in ("ssm", "hybrid"):
        return model_cfg
    return dataclasses.replace(model_cfg, long_context_window=4096)
