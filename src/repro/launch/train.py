"""Decentralized K-GT-Minimax training driver.

Runs real federated minimax training (DRO over the selected architecture)
with the full substrate: heterogeneous synthetic data, round batching,
schedules, checkpointing, and per-round diagnostics.  On this CPU container
it trains reduced configs / paper-toy end-to-end; on a TPU cluster the same
driver lowers onto the decentralized mesh via ``--mesh production``.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch paper-toy --rounds 50
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --rounds 20 --clients 4 --local-steps 4 --algorithm local_sgda
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs import registry
from repro.configs.base import AlgorithmConfig, MinimaxConfig, TrainConfig
from repro.core import kgt_minimax as kgt
from repro.core import mixing as mixing_lib
from repro.core import objectives, topology
from repro.data import synthetic as data_lib
from repro.optim import schedules


def train(args) -> dict:
    cfg = registry.get_model_config(args.arch)
    if args.reduced:
        cfg = registry.reduced(cfg)
    algo = AlgorithmConfig(
        algorithm=args.algorithm,
        num_clients=args.clients,
        local_steps=args.local_steps,
        eta_cx=args.eta_cx,
        eta_cy=args.eta_cy,
        eta_sx=args.eta_s,
        eta_sy=args.eta_s,
        topology=args.topology,
        mixing_impl=args.mixing_impl,
        gossip_dtype=args.gossip_dtype,
        # getattr: programmatic callers (tests) build a bare Namespace
        gossip_backend=getattr(args, "gossip_backend", "auto"),
    )
    minimax = MinimaxConfig(num_groups=args.groups, mu=args.mu)

    key = jax.random.PRNGKey(args.seed)
    kd, ki, kt = jax.random.split(key, 3)

    dm = data_lib.make_data_model(
        kd, vocab_size=cfg.vocab_size, num_groups=args.groups,
        num_clients=algo.num_clients, alpha=args.alpha)
    problem = objectives.dro_problem(
        cfg, num_groups=args.groups, mu=args.mu, remat=False)

    init_b = jax.tree.map(
        lambda x: x[0],
        data_lib.round_batches(
            dm, jax.random.fold_in(kd, 1), local_steps=1,
            num_clients=algo.num_clients, per_client_batch=args.batch,
            seq_len=args.seq_len, cfg=cfg))
    state = kgt.init_state(problem, algo, ki, init_batch=init_b,
                           init_keys=jax.random.split(ki, algo.num_clients))

    sched = schedules.get_schedule(args.schedule, args.rounds, args.warmup)
    if getattr(args, "mesh", "host") == "decentralized":
        # Sharded path: the same jit program the dry-run lowers for a pod,
        # here over whatever local devices exist (clients axis = n_devices).
        # repro.dist places the leading clients dim of the K-GT-Minimax
        # state on the "clients" mesh axis; only gossip crosses clients.
        from repro.configs.base import InputShape, MeshConfig
        from repro.dist import compat
        from repro.launch import mesh as mesh_lib
        from repro.launch import steps as steps_lib

        # clients axis must divide the state's leading dim (= num_clients):
        # use the largest device count that does.
        import math
        n_dev = math.gcd(len(jax.devices()), algo.num_clients)
        mesh = mesh_lib.local_mesh(n_dev)
        mcfg = MeshConfig(num_clients=algo.num_clients, fsdp=1, model=1,
                          param_mode="replicated", remat=False)
        shape = InputShape(name="train_cli", seq_len=args.seq_len,
                           global_batch=args.batch * algo.num_clients,
                           kind="train")
        with compat.use_mesh(mesh):
            step, _, _, _, (state_shard, _, _) = steps_lib.build_train_round(
                cfg, shape, mesh, mcfg, algo=algo, minimax=minimax,
                lr_scale=sched)
        state = jax.device_put(state, state_shard)
    else:
        step = jax.jit(kgt.make_round_step(problem, algo, lr_scale=sched))
    w = topology.mixing_matrix(algo.topology, algo.num_clients)
    print(f"[train] {cfg.name}: {sum(x.size for x in jax.tree.leaves(state.x))/1e6:.2f}M "
          f"client-stacked params, n={algo.num_clients}, K={algo.local_steps}, "
          f"p={topology.spectral_gap(w):.3f}, algo={algo.algorithm}", flush=True)

    history = []
    t0 = time.time()
    for t in range(args.rounds):
        kb = jax.random.fold_in(kt, t)
        batches = data_lib.round_batches(
            dm, kb, local_steps=algo.local_steps, num_clients=algo.num_clients,
            per_client_batch=args.batch, seq_len=args.seq_len, cfg=cfg)
        keys = jax.random.split(
            jax.random.fold_in(kb, 999), algo.local_steps * algo.num_clients
        ).reshape(algo.local_steps, algo.num_clients, 2)
        state = step(state, batches, keys)

        if t % args.log_every == 0 or t == args.rounds - 1:
            from repro.models import per_group_loss as _pgl

            xbar = kgt.mean_over_clients(state.x)
            eval_b = jax.tree.map(lambda x: x[0, 0], batches)  # (k=0, client 0)
            f_val = float(problem.value(xbar, state.y.mean(0), eval_b, None))
            losses, _ = _pgl(xbar, eval_b, cfg, num_groups=args.groups)
            rec = {
                "round": t,
                "f_bar": f_val,
                "mean_loss": float(losses.mean()),
                "consensus_x": float(mixing_lib.consensus_error(state.x)),
                "y_bar_norm": float(jnp.linalg.norm(state.y.mean(0))),
                "wall_s": round(time.time() - t0, 1),
            }
            history.append(rec)
            print(f"[train] round {t:4d}  f(x̄,ȳ)={rec['f_bar']:.4f}  "
                  f"ℓ̄={rec['mean_loss']:.4f}  "
                  f"Ξx={rec['consensus_x']:.3e}  |ȳ|={rec['y_bar_norm']:.3f}  "
                  f"({rec['wall_s']}s)", flush=True)

        if args.checkpoint_every and (t + 1) % args.checkpoint_every == 0:
            path = os.path.join(args.checkpoint_dir, f"round_{t+1:06d}.npz")
            ckpt_lib.save(path, state, metadata={"round": t + 1, "arch": cfg.name})
            print(f"[train] checkpoint -> {path}", flush=True)

    return {"history": history, "final_consensus": history[-1]["consensus_x"]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-toy")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized variant of the arch")
    ap.add_argument("--algorithm", default="kgt_minimax",
                    choices=["kgt_minimax", "dsgda", "local_sgda", "gt_gda"])
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4, help="per-client batch")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--mu", type=float, default=1.0)
    ap.add_argument("--alpha", type=float, default=0.3, help="Dirichlet heterogeneity")
    ap.add_argument("--eta-cx", type=float, default=0.05)
    ap.add_argument("--eta-cy", type=float, default=0.5)
    ap.add_argument("--eta-s", type=float, default=0.7)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "decentralized"],
                    help="host: plain single-device jit; decentralized: the "
                         "repro.dist-sharded round over the local device mesh")
    ap.add_argument("--topology", default="ring")
    from repro.kernels.ops import GOSSIP_BACKENDS

    ap.add_argument("--mixing-impl", default="dense",
                    choices=list(mixing_lib.MIXING_IMPLS))
    ap.add_argument("--gossip-dtype", default="float32")
    ap.add_argument("--gossip-backend", default="auto",
                    choices=list(GOSSIP_BACKENDS),
                    help="pallas_packed epilogue backend (auto: Pallas "
                         "kernel on TPU, packed-xla oracle elsewhere)")
    ap.add_argument("--schedule", default="constant")
    ap.add_argument("--warmup", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    result = train(args)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
