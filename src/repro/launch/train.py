"""Decentralized K-GT-Minimax training driver.

Runs real federated minimax training (DRO over the selected architecture)
with the full substrate: heterogeneous synthetic data, round batching,
schedules, checkpointing, and streaming diagnostics.  On this CPU container
it trains reduced configs / paper-toy end-to-end; on a TPU cluster the same
driver lowers onto the decentralized mesh via ``--mesh decentralized``.

Execution is delegated to ``repro.engine`` (``--engine scan``, the
default): R-round chunks compile as a single ``lax.scan`` program with
device-side data sampling and an on-device metrics buffer, so the host
pays one dispatch + one metrics read per chunk instead of per round.
``--engine host`` keeps the historical per-round loop (same sampler, same
metrics — the trajectories are bit-identical, see tests/test_engine.py)
for A/B and debugging.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch paper-toy --rounds 50
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --rounds 20 --clients 4 --local-steps 4 --algorithm local_sgda
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro import engine as engine_lib
from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs import registry
from repro.configs.base import AlgorithmConfig, MinimaxConfig
from repro.core import adversary as adversary_lib
from repro.core import kgt_minimax as kgt
from repro.core import mixing as mixing_lib
from repro.core import objectives, topology
from repro.core import sparse_topology as sparse_lib
from repro.core import stochastic_topology as stoch_lib
from repro.data import synthetic as data_lib
from repro.optim import schedules


# (key, format) pairs rendered when present: a metrics schema without
# f_bar/mean_loss (e.g. quadratic_metrics_fn rows) must not KeyError the
# console stream — format only the keys the row actually carries.
_RECORD_FORMATS = (
    ("f_bar", "f(x̄,ȳ)={:.4f}"),
    ("phi_grad_norm", "‖∇Φ‖={:.4f}"),
    ("mean_loss", "ℓ̄={:.4f}"),
    ("eval_loss", "ℓ_eval={:.4f}"),
    ("consensus_x", "Ξx={:.3e}"),
    ("y_bar_norm", "|ȳ|={:.3f}"),
)


def _format_record(rec: dict) -> str:
    parts = []
    if "round" in rec:
        parts.append(f"round {int(rec['round']):4d}")
    for key, fmt in _RECORD_FORMATS:
        if key in rec:
            parts.append(fmt.format(rec[key]))
    parts.append(f"({rec.get('wall_s', 0)}s)")
    return "[train] " + "  ".join(parts)


def _print_record(rec: dict) -> None:
    print(_format_record(rec), flush=True)


def _stderr_event_format(event: dict):
    """The console view of the telemetry stream: metric rows render exactly
    as the historical print logging; everything else stays JSONL-only."""
    if event.get("type") != "metrics":
        return None
    return _format_record(
        {k: v for k, v in event.items() if k not in ("v", "type", "t")})


def _build_telemetry(args, algo, cfg, state):
    """(telemetry, ledger, profiler) from the CLI flags.

    The stderr sink is always on (it *is* the historical console logging);
    the JSONL sink, the communication ledger, and the health gauges arm
    only with ``--telemetry-out``, and the profiler only with
    ``--profile-dir`` — so a plain run does no extra device work
    (tests/test_obs.py pins the bit-identity of the trajectory).
    """
    from repro import obs

    tel_path = getattr(args, "telemetry_out", None)
    sinks = [obs.StderrSink(_stderr_event_format)]
    ledger = None
    if tel_path:
        sinks.append(obs.JsonlSink(tel_path))
        ledger = obs.ledger_for_state(algo, state)
    telemetry = obs.Telemetry(sinks)
    profile_dir = getattr(args, "profile_dir", None)
    profiler = (obs.Profiler(profile_dir,
                             num_rounds=getattr(args, "profile_rounds", 0))
                if profile_dir else None)
    if tel_path:
        telemetry.meta(
            "train", arch=cfg.name, algorithm=algo.algorithm,
            n=algo.num_clients, local_steps=algo.local_steps,
            topology=algo.topology, mixing_impl=algo.mixing_impl,
            gossip_dtype=algo.gossip_dtype,
            gossip_compress=algo.gossip_compress,
            num_byzantine=algo.num_byzantine, attack=algo.attack,
            participation=algo.participation_rate,
            rounds=args.rounds, seed=args.seed,
            ledger=ledger.describe())
    return telemetry, ledger, profiler


def _compile_cache(args):
    """Resolve ``--compile-cache`` / ``$REPRO_COMPILE_CACHE`` into a
    ``repro.sweep.cache.CompileCache`` (arming jax's persistent compilation
    cache under the same root), or None when off.  Flag wins over env."""
    from repro.sweep import cache as cache_lib

    spec = getattr(args, "compile_cache", None)
    if spec is None:
        return cache_lib.from_env()
    s = str(spec).strip().lower()
    if s in cache_lib._OFF_VALUES:
        return None
    root = (cache_lib.default_root() if s in cache_lib._ON_VALUES
            else str(spec))
    cache_lib.enable_xla_cache(os.path.join(root, "xla"))
    return cache_lib.CompileCache(os.path.join(root, "aot"))


def _train_statics(args) -> tuple:
    """The cache-key statics of the train chunk program: every CLI argument
    that can reach the traced program or its *baked* constants (the data
    model, sampler keys, and schedule are closure constants derived from
    these — see the warning in ``repro.sweep.cache``).  Only output-path
    arguments are excluded."""
    skip = {"out", "telemetry_out", "profile_dir", "profile_rounds",
            "checkpoint_dir", "checkpoint_every", "compile_cache"}
    return tuple(sorted((k, repr(v)) for k, v in vars(args).items()
                        if k not in skip))


def _build_mesh_programs(args, cfg, algo, minimax, sched, sampler, metrics_fn,
                         engine_mode):
    """The repro.dist-sharded program over the local device mesh: the chunk
    builder (scan engine) or the per-round step (host engine) — only the
    one the selected engine runs."""
    import math

    from repro.configs.base import InputShape, MeshConfig
    from repro.dist import compat
    from repro.launch import mesh as mesh_lib
    from repro.launch import steps as steps_lib

    # clients axis must divide the state's leading dim (= num_clients):
    # use the largest device count that does.
    n_dev = math.gcd(len(jax.devices()), algo.num_clients)
    mesh = mesh_lib.local_mesh(n_dev)
    mcfg = MeshConfig(num_clients=algo.num_clients, fsdp=1, model=1,
                      param_mode="replicated", remat=False)
    shape = InputShape(name="train_cli", seq_len=args.seq_len,
                       global_batch=args.batch * algo.num_clients,
                       kind="train")
    with compat.use_mesh(mesh):
        if engine_mode == "scan":
            build_chunk, _, state_shard = steps_lib.build_train_chunk(
                cfg, shape, mesh, mcfg, algo=algo, minimax=minimax,
                lr_scale=sched, sampler=sampler, metrics_fn=metrics_fn,
                log_every=args.log_every)
            return None, build_chunk, state_shard
        step, _, _, _, (state_shard, _, _) = steps_lib.build_train_round(
            cfg, shape, mesh, mcfg, algo=algo, minimax=minimax,
            lr_scale=sched)
    return step, None, state_shard


def train(args) -> dict:
    cfg = registry.get_model_config(args.arch)
    if args.reduced:
        cfg = registry.reduced(cfg)
    algo = AlgorithmConfig(
        algorithm=args.algorithm,
        num_clients=args.clients,
        local_steps=args.local_steps,
        eta_cx=args.eta_cx,
        eta_cy=args.eta_cy,
        eta_sx=args.eta_s,
        eta_sy=args.eta_s,
        topology=args.topology,
        mixing_impl=args.mixing_impl,
        gossip_dtype=args.gossip_dtype,
        # getattr: programmatic callers (tests) build a bare Namespace
        gossip_backend=getattr(args, "gossip_backend", "auto"),
        gossip_compress=(None if getattr(args, "gossip_compress", None)
                         in (None, "none") else args.gossip_compress),
        topology_family=getattr(args, "topology_family", "static"),
        edge_prob=getattr(args, "edge_prob", 0.5),
        client_drop_prob=getattr(args, "client_drop_prob", 0.3),
        participation_rate=getattr(args, "participation", 1.0),
        topology_seed=(getattr(args, "topology_seed", None)
                       if getattr(args, "topology_seed", None) is not None
                       else args.seed),
        num_byzantine=getattr(args, "num_byzantine", 0),
        attack=getattr(args, "attack", "sign_flip"),
        attack_scale=getattr(args, "attack_scale", 1.0),
        robust_trim=getattr(args, "robust_trim", 1),
    )
    random_w = algo.topology_family != "static"
    part = algo.participation_rate < 1.0
    byz = algo.num_byzantine > 0
    minimax = MinimaxConfig(num_groups=args.groups, mu=args.mu)
    engine_mode = getattr(args, "engine", "scan")
    chunk_rounds = max(1, min(int(getattr(args, "chunk", 16)),
                              max(args.rounds, 1)))
    mesh_mode = getattr(args, "mesh", "host")

    key = jax.random.PRNGKey(args.seed)
    kd, ki, kt = jax.random.split(key, 3)

    dm = data_lib.make_data_model(
        kd, vocab_size=cfg.vocab_size, num_groups=args.groups,
        num_clients=algo.num_clients, alpha=args.alpha)
    problem = objectives.dro_problem(
        cfg, num_groups=args.groups, mu=args.mu, remat=False)

    init_b = jax.tree.map(
        lambda x: x[0],
        data_lib.round_batches(
            dm, jax.random.fold_in(kd, 1), local_steps=1,
            num_clients=algo.num_clients, per_client_batch=args.batch,
            seq_len=args.seq_len, cfg=cfg))
    state = kgt.init_state(problem, algo, ki, init_batch=init_b,
                           init_keys=jax.random.split(ki, algo.num_clients))

    sched = schedules.get_schedule(args.schedule, args.rounds, args.warmup)

    # Device-side data path: the per-round sampler (a pure function of the
    # round index, callable inside the scanned chunk) and one fixed held-out
    # eval batch — logged train metrics use the round's own data, eval
    # metrics use data the optimizer never sees.
    sampler = engine_lib.make_dro_sampler(
        dm, kt, local_steps=algo.local_steps, num_clients=algo.num_clients,
        per_client_batch=args.batch, seq_len=args.seq_len, cfg=cfg)
    if random_w or part or byz:
        # churn + adversary axes ride the sampler slot: per-round W /
        # participation mask / attack drawn on device from the round index
        # (checkpoint-restore exact)
        if mesh_mode == "decentralized":
            raise ValueError(
                "--topology-family/--participation/--num-byzantine are not "
                "supported with --mesh decentralized yet (the sharded chunk "
                "builder bakes a static W); run on the host mesh")
        topo_key = jax.random.PRNGKey(algo.topology_seed)
        w_fn = None
        if random_w:
            if algo.mixing_impl.startswith("sparse_"):
                # the sampled W rides the extras slot as a SparseTopology
                # pytree drawn on the support graph's neighbor lists —
                # no (n, n) array anywhere on the churn path
                support = sparse_lib.sparse_mixing_matrix(
                    algo.topology, algo.num_clients)
                w_fn = sparse_lib.make_sparse_w_sampler(
                    algo.topology_family, support, topo_key,
                    edge_prob=algo.edge_prob,
                    client_drop_prob=algo.client_drop_prob)
            else:
                base_w = (topology.mixing_matrix(algo.topology,
                                                 algo.num_clients)
                          if algo.topology_family == "dropout" else None)
                w_fn = stoch_lib.make_w_sampler(
                    algo.topology_family, algo.num_clients, topo_key,
                    base_w=base_w, edge_prob=algo.edge_prob,
                    client_drop_prob=algo.client_drop_prob)
        mask_fn = None
        if part:
            mask_fn = stoch_lib.make_participation_sampler(
                algo.num_clients, topo_key, algo.participation_rate)
        attack_fn = None
        if byz:
            attack_fn = adversary_lib.make_attack_sampler(
                algo.num_clients, topo_key,
                num_byzantine=algo.num_byzantine, attack=algo.attack,
                scale=algo.attack_scale)
        sampler = engine_lib.with_topology(
            sampler, w_fn=w_fn, mask_fn=mask_fn, attack_fn=attack_fn)
    eval_b = engine_lib.held_out_eval_batch(
        dm, jax.random.fold_in(kd, 2), num_clients=algo.num_clients,
        per_client_batch=args.batch, seq_len=args.seq_len, cfg=cfg)
    metrics_fn = engine_lib.dro_metrics_fn(
        problem, cfg, num_groups=args.groups, eval_batch=eval_b)

    cache = _compile_cache(args)
    if mesh_mode == "decentralized":
        # Sharded path: the same jit programs the dry-run lowers for a pod,
        # here over whatever local devices exist (clients axis = n_devices).
        # repro.dist places the leading clients dim of the K-GT-Minimax
        # state on the "clients" mesh axis; only gossip crosses clients.
        step, build_chunk, state_shard = _build_mesh_programs(
            args, cfg, algo, minimax, sched, sampler, metrics_fn, engine_mode)
        state = jax.device_put(state, state_shard)
    else:
        round_step = kgt.make_round_step(problem, algo, lr_scale=sched,
                                         traced_w=random_w,
                                         participation=part,
                                         byzantine=byz)
        step = jax.jit(round_step)
        build_chunk = engine_lib.make_chunk_builder(
            round_step, sampler, metrics_fn, log_every=args.log_every)
        if cache is not None and engine_mode == "scan":
            # the AOT layer applies only on the host path: the sharded mesh
            # programs embed their device assignment (layer 1 — jax's own
            # persistent cache — still covers them via _compile_cache above)
            build_chunk = engine_lib.timed_chunk_builder(
                build_chunk, cache=cache, statics=_train_statics(args))
    if random_w:
        # W is redrawn every round: a static spectral gap would mislabel
        # the run, so report the family (and its rate) instead
        topo_part = (f"family={algo.topology_family}"
                     + (f" (edge_prob={algo.edge_prob})"
                        if algo.topology_family == "erdos_renyi" else "")
                     + (f" (drop={algo.client_drop_prob})"
                        if algo.topology_family == "dropout" else ""))
    elif (algo.mixing_impl.startswith("sparse_")
          and algo.num_clients > stoch_lib.DENSE_MATERIALIZATION_LIMIT):
        # densifying just to report an eigengap defeats the sparse path
        support = sparse_lib.sparse_mixing_matrix(
            algo.topology, algo.num_clients)
        topo_part = (f"{algo.topology} (sparse, "
                     f"max_deg={support.max_degree})")
    else:
        w = topology.mixing_matrix(algo.topology, algo.num_clients)
        topo_part = f"p={topology.spectral_gap(w):.3f}"
    if part:
        topo_part += f", participation={algo.participation_rate}"
    if byz:
        topo_part += (f", byzantine={algo.num_byzantine} "
                      f"({algo.attack} x{algo.attack_scale})")
    print(f"[train] {cfg.name}: {sum(x.size for x in jax.tree.leaves(state.x))/1e6:.2f}M "
          f"client-stacked params, n={algo.num_clients}, K={algo.local_steps}, "
          f"{topo_part}, algo={algo.algorithm}, "
          f"engine={engine_mode}"
          + (f" (chunk={chunk_rounds})" if engine_mode == "scan" else ""),
          flush=True)

    telemetry, ledger, profiler = _build_telemetry(args, algo, cfg, state)
    try:
        if engine_mode == "scan":
            from repro import obs

            # the telemetry hook routes metric rows to the stderr sink
            # (the historical console log) and, with --telemetry-out, the
            # ledger + health gauges into the JSONL stream
            hooks = [engine_lib.telemetry_hook(
                telemetry, ledger=ledger,
                health_fn=obs.health_gauges if ledger is not None else None)]
            if args.checkpoint_every:
                hooks.append(engine_lib.checkpoint_hook(
                    args.checkpoint_dir, args.checkpoint_every,
                    metadata={"arch": cfg.name}, verbose=True))
            if profiler is not None:
                profiler.start()
                hooks.append(profiler.hook)

            state, history = engine_lib.run(
                state, build_chunk, total_rounds=args.rounds,
                chunk_rounds=chunk_rounds, hooks=hooks,
                # chunk boundaries land on every checkpoint multiple, so the
                # requested cadence is honored exactly (matches --engine host)
                boundary_every=args.checkpoint_every or None,
                telemetry=telemetry if ledger is not None else None)
        else:
            history = _host_loop(args, state, step, sampler, metrics_fn, cfg,
                                 telemetry=telemetry, ledger=ledger)
    finally:
        if profiler is not None:
            profiler.stop()
        telemetry.close()

    return {
        "history": history,
        "final_consensus": history[-1]["consensus_x"] if history else None,
    }


def _host_loop(args, state, step, sampler, metrics_fn, cfg,
               telemetry=None, ledger=None):
    """The historical per-round loop (``--engine host``): per-round jit
    dispatch with eagerly sampled batches.  Kept as the A/B reference — it
    runs the same sampler and metrics as the scan engine, so trajectories
    and logged diagnostics are identical, just slower.  Metric rows flow
    through the telemetry stream (the stderr sink renders the historical
    console line); the ledger accumulates per logged interval."""
    sample = jax.jit(sampler)
    metrics = jax.jit(metrics_fn)
    history = []
    # monotonic clock: wall_s stamps must never go backwards mid-run
    # (wall-clock deltas can, under NTP slew) — matches engine.py
    t0 = time.perf_counter()
    prev_logged = 0
    for t in range(args.rounds):
        batches, keys, extras = engine_lib.split_sampled(sample(jnp.int32(t)))
        state = step(state, batches, keys, *extras)

        if t % args.log_every == 0 or t == args.rounds - 1:
            rec = engine_lib.row_to_record(
                jax.device_get(metrics(state, batches)), t)
            rec["wall_s"] = round(time.perf_counter() - t0, 3)
            history.append(rec)
            if telemetry is not None:
                telemetry.metrics(rec)
            else:
                _print_record(rec)
            if ledger is not None:
                ledger.add_rounds(t + 1 - prev_logged)
                telemetry.emit(ledger.event(rounds=t + 1 - prev_logged,
                                            round=t + 1))
                prev_logged = t + 1

        if args.checkpoint_every and (t + 1) % args.checkpoint_every == 0:
            path = os.path.join(args.checkpoint_dir, f"round_{t+1:06d}.npz")
            ckpt_lib.save(path, state, metadata={"round": t + 1, "arch": cfg.name})
            print(f"[train] checkpoint -> {path}", flush=True)
    return history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-toy")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized variant of the arch")
    ap.add_argument("--algorithm", default="kgt_minimax",
                    choices=["kgt_minimax", "dsgda", "local_sgda", "gt_gda"])
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4, help="per-client batch")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--mu", type=float, default=1.0)
    ap.add_argument("--alpha", type=float, default=0.3, help="Dirichlet heterogeneity")
    ap.add_argument("--eta-cx", type=float, default=0.05)
    ap.add_argument("--eta-cy", type=float, default=0.5)
    ap.add_argument("--eta-s", type=float, default=0.7)
    ap.add_argument("--engine", default="scan", choices=["scan", "host"],
                    help="scan: repro.engine chunked lax.scan over rounds "
                         "with on-device sampling/metrics; host: per-round "
                         "dispatch (A/B fallback, bit-identical trajectory)")
    ap.add_argument("--chunk", type=int, default=16,
                    help="rounds per compiled scan chunk (--engine scan)")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "decentralized"],
                    help="host: plain single-device jit; decentralized: the "
                         "repro.dist-sharded round over the local device mesh")
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--topology-family", default="static",
                    choices=list(stoch_lib.TOPOLOGY_FAMILIES),
                    help="per-round random topology (repro.core."
                         "stochastic_topology): static keeps --topology "
                         "fixed; erdos_renyi draws G(n, --edge-prob) with "
                         "Metropolis weights; pairwise averages one random "
                         "pair per round; dropout drops each client's links "
                         "with --client-drop-prob (self-loop fallback)")
    ap.add_argument("--edge-prob", type=float, default=0.5,
                    help="erdos_renyi: per-round link probability")
    ap.add_argument("--client-drop-prob", type=float, default=0.3,
                    help="dropout family: per-round P[client drops links]")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="partial participation: per-round P[client active]; "
                         "< 1 freezes inactive clients' (theta, c) for the "
                         "round (Bernoulli mask, self-loop fallback)")
    ap.add_argument("--topology-seed", type=int, default=None,
                    help="seed of the W/mask/attack sampling streams "
                         "(default: --seed)")
    ap.add_argument("--num-byzantine", type=int, default=0,
                    help="Byzantine clients (ids 0..f-1): their outgoing "
                         "round deltas are replaced per --attack before "
                         "gossip (repro.core.adversary); pair with a robust "
                         "--mixing-impl (coord_median / trimmed_mean) to "
                         "tolerate them")
    ap.add_argument("--attack", default="sign_flip",
                    choices=list(adversary_lib.ATTACKS),
                    help="Byzantine attack model applied to attackers' "
                         "outgoing deltas")
    ap.add_argument("--attack-scale", type=float, default=1.0,
                    help="attack magnitude multiplier")
    ap.add_argument("--robust-trim", type=int, default=1,
                    help="trimmed_mean: neighbor values trimmed per side "
                         "per coordinate")
    from repro.kernels.ops import GOSSIP_BACKENDS

    ap.add_argument("--mixing-impl", default="dense",
                    choices=list(mixing_lib.MIXING_IMPLS))
    ap.add_argument("--gossip-dtype", default="float32")
    from repro.core.compression import COMPRESS_METHODS

    ap.add_argument("--gossip-compress", default="none",
                    choices=["none", *COMPRESS_METHODS],
                    help="error-feedback quantized gossip: compress the "
                         "transmitted round delta (bf16 | int8) and carry "
                         "the quantization residual as per-client EF state; "
                         "requires a packed --mixing-impl (pallas_packed / "
                         "fused_round)")
    ap.add_argument("--gossip-backend", default="auto",
                    choices=list(GOSSIP_BACKENDS),
                    help="pallas_packed epilogue backend (auto: Pallas "
                         "kernel on TPU, packed-xla oracle elsewhere)")
    ap.add_argument("--schedule", default="constant")
    ap.add_argument("--warmup", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--telemetry-out", default=None,
                    help="write the structured telemetry stream (spans, "
                         "metric rows, communication ledger, health gauges) "
                         "as JSONL to this path; summarize it with "
                         "`python -m repro.obs.report <path>`")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler Perfetto trace into this "
                         "directory (open in Perfetto/TensorBoard)")
    ap.add_argument("--profile-rounds", type=int, default=0,
                    help="close the profiler capture window after this many "
                         "rounds (0 = profile the whole run)")
    ap.add_argument("--compile-cache", default=None, metavar="DIR|on|off",
                    help="persistent compile cache (repro.sweep.cache): a "
                         "directory roots it, 'on' uses the default "
                         "results/.xla_cache, 'off' disables; default: "
                         "$REPRO_COMPILE_CACHE")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    result = train(args)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
