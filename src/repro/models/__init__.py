from repro.models.model import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_loss,
    param_count,
    per_group_loss,
    token_losses,
)
