"""GQA attention: naive (oracle / small-seq train), q-chunked (long prefill),
decode-over-cache, with optional sliding window.  The Pallas flash kernel in
``repro.kernels`` is the TPU-target implementation of the same math; selection
happens in ``repro.models.transformer`` via the attention ``impl`` knob.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


def init_attention(key, cfg, d_model: int):
    """cfg: ModelConfig (uses num_heads / num_kv_heads / head_dim / qkv_bias)."""
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, (d_model, cfg.num_heads, hd), in_axis=0),
        "wk": dense_init(kk, (d_model, cfg.num_kv_heads, hd), in_axis=0),
        "wv": dense_init(kv, (d_model, cfg.num_kv_heads, hd), in_axis=0),
        "wo": dense_init(ko, (cfg.num_heads, hd, d_model), in_axis=0),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads, hd))
        p["bk"] = jnp.zeros((cfg.num_kv_heads, hd))
        p["bv"] = jnp.zeros((cfg.num_kv_heads, hd))
    return p


def qkv_project(params, x, cfg, positions, compute_dtype=jnp.bfloat16):
    w = lambda p: p.astype(compute_dtype)
    q = jnp.einsum("...sd,dhk->...shk", x, w(params["wq"]))
    k = jnp.einsum("...sd,dhk->...shk", x, w(params["wk"]))
    v = jnp.einsum("...sd,dhk->...shk", x, w(params["wv"]))
    if "bq" in params:
        q = q + w(params["bq"])
        k = k + w(params["bk"])
        v = v + w(params["bv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_project(params, ctx, compute_dtype=jnp.bfloat16):
    return jnp.einsum("...shk,hkd->...sd", ctx, params["wo"].astype(compute_dtype))


def _expand_kv(k, n_rep: int):
    """(..., S, KV, D) -> (..., S, KV*n_rep, D) by repeating each kv head."""
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=-2)


def causal_mask(sq: int, sk: int, q_offset: int = 0, window: int = 0):
    """Boolean (sq, sk) mask: True = attend. Query i at absolute position
    q_offset + i attends keys at absolute positions 0..sk-1 with j <= i and,
    if window > 0, i - j < window."""
    qi = q_offset + jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > qi - window
    return m


def naive_attention(q, k, v, *, window: int = 0, q_offset: int = 0):
    """Reference attention.  q: (B,Sq,H,D); k,v: (B,Sk,KV,D).

    GQA via grouped einsums — the kv tensors are never materialized at H
    heads (an explicit repeat forces GSPMD to all-gather the expanded kv over
    a seq-sharded mesh axis: 42 GiB/step measured on qwen2 train_4k)."""
    b, sq, h, d = q.shape
    kv = k.shape[-2]
    g = h // kv
    scale = d ** -0.5
    mask = causal_mask(sq, k.shape[-3], q_offset, window)
    if g == 1:
        # MHA: direct einsum (the grouped form's singleton dim measurably
        # degrades GSPMD sharding decisions)
        logits = jnp.einsum("...qhd,...khd->...hqk", q, k).astype(jnp.float32) * scale
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        return jnp.einsum("...hqk,...khd->...qhd", probs, v)
    qg = q.reshape(b, sq, kv, g, d)
    logits = jnp.einsum("...qhgd,...khd->...hgqk", qg, k).astype(jnp.float32) * scale
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("...hgqk,...khd->...qhgd", probs, v)
    return ctx.reshape(b, sq, h, d)


def qchunk_attention(q, k, v, *, window: int = 0, q_chunk: int = 512):
    """Memory-bounded attention for long no-grad prefill: lax.map over query
    blocks (scores materialized per block only)."""
    b, s, h, d = q.shape
    qc = min(q_chunk, s)
    nq = -(-s // qc)
    pad = nq * qc - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qs = q.reshape(b, nq, qc, h, d).transpose(1, 0, 2, 3, 4)  # (nq,B,qc,H,D)

    def one(args):
        i, qi = args
        return naive_attention(qi, k, v, window=window, q_offset=i * qc)

    out = jax.lax.map(one, (jnp.arange(nq), qs))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nq * qc, h, d)
    return out[:, :s]


def decode_attention(q, k_cache, v_cache, valid):
    """Single-token decode: q (B,1,H,D) against a cache (B,S,KV,D) with a
    boolean validity mask ``valid`` (S,) — False for slots not yet written
    (cold start).  GQA is handled by grouping q heads — the kv cache is never
    materialized at H heads (GSPMD-friendly: no repeat, contraction stays
    partial over a seq-sharded cache + small all-reduce)."""
    b, one, h, d = q.shape
    kv = k_cache.shape[-2]
    g = h // kv
    scale = d ** -0.5
    if g == 1:  # MHA: direct form (see naive_attention)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache).astype(jnp.float32) * scale
        logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v_cache)
    qg = q.reshape(b, one, kv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache).astype(jnp.float32) * scale
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v_cache)
    return ctx.reshape(b, one, h, d)
