"""Core layers: initializers, RMSNorm, RoPE, SwiGLU MLP — pure functional JAX."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32):
    """LeCun-normal-ish init, fan-in along ``in_axis``."""
    fan_in = shape[in_axis]
    return jax.random.normal(key, shape, dtype) * (1.0 / jnp.sqrt(fan_in))


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


def rms_norm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # (...,S,1,D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "down": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }


def mlp(params, x, compute_dtype=jnp.bfloat16):
    w = lambda p: p.astype(compute_dtype)
    h = jax.nn.silu(x @ w(params["gate"])) * (x @ w(params["up"]))
    return h @ w(params["down"])
