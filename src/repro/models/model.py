"""Top-level language model: embeddings -> stacked decoder -> head, plus the
per-group loss used by the DRO minimax objective, KV/state cache management,
and the decode step.

Modality frontends (VLM vision encoder, audio EnCodec) are stubs per the
brief: batches carry precomputed ``prefix`` embeddings (VLM) or multi-codebook
token streams (audio); only the transformer backbone is real.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import context as dist_ctx
from repro.models import transformer as tf
from repro.models.layers import embed_init, rms_norm


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    k_embed, k_stack, k_head = jax.random.split(key, 3)
    if cfg.num_codebooks:
        embed = embed_init(k_embed, (cfg.num_codebooks, cfg.vocab_size, cfg.d_model))
    else:
        embed = embed_init(k_embed, (cfg.vocab_size, cfg.d_model))
    params = {
        "embed": embed,
        "stack": tf.init_stack(k_stack, cfg),
        "final_norm": jnp.zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        if cfg.num_codebooks:
            params["head"] = embed_init(
                k_head, (cfg.num_codebooks, cfg.d_model, cfg.vocab_size))
        else:
            params["head"] = embed_init(k_head, (cfg.d_model, cfg.vocab_size))
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig, compute_dtype):
    emb = params["embed"].astype(compute_dtype)
    if cfg.num_codebooks:
        # tokens: (B,S,ncb) -> sum of per-codebook embeddings
        parts = [emb[c][tokens[..., c]] for c in range(cfg.num_codebooks)]
        return sum(parts)
    return emb[tokens]


def lm_head(params, x, cfg: ModelConfig, compute_dtype):
    if cfg.tie_embeddings:
        w = params["embed"].astype(compute_dtype)
        if cfg.num_codebooks:
            return jnp.einsum("bsd,cvd->bscv", x, w)
        return jnp.einsum("bsd,vd->bsv", x, w)
    w = params["head"].astype(compute_dtype)
    if cfg.num_codebooks:
        return jnp.einsum("bsd,cdv->bscv", x, w)
    return jnp.einsum("bsd,dv->bsv", x, w)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def backbone(
    params,
    batch: Dict[str, Any],
    cfg: ModelConfig,
    *,
    mode: str = "train",
    compute_dtype=jnp.bfloat16,
    caches=None,
    pos=None,
    remat: bool = False,
):
    """Everything up to (and incl.) the final norm.  Returns (hidden (B,S,d),
    new_caches, aux)."""
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg, compute_dtype)
    if "embed_bias" in batch:  # adversarial objective: universal perturbation
        x = x + batch["embed_bias"].astype(compute_dtype)
    b, s = x.shape[0], x.shape[1]
    offset = 0
    if cfg.num_prefix_tokens and "prefix" in batch and mode != "decode":
        prefix = batch["prefix"].astype(compute_dtype)
        x = jnp.concatenate([prefix, x], axis=1)
        offset = prefix.shape[1]
    if mode == "decode":
        positions = jnp.full((b, 1), pos, jnp.int32)
    else:
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :].repeat(b, 0)

    x, new_caches, aux = tf.stack_forward(
        params["stack"], x, cfg, mode=mode, positions=positions, caches=caches,
        pos=pos, compute_dtype=compute_dtype, remat=remat,
        attn_impl="qchunk" if mode == "prefill" else "auto",
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if offset:
        x = x[:, offset:]
    return x, new_caches, aux


def forward(
    params,
    batch: Dict[str, Any],
    cfg: ModelConfig,
    *,
    mode: str = "train",
    compute_dtype=jnp.bfloat16,
    caches=None,
    pos=None,
    remat: bool = False,
    last_only: bool = False,
):
    """Returns (logits, new_caches, aux).  ``last_only`` computes the head on
    the final position only (prefill servers)."""
    x, new_caches, aux = backbone(
        params, batch, cfg, mode=mode, compute_dtype=compute_dtype,
        caches=caches, pos=pos, remat=remat)
    if last_only:
        x = x[:, -1:]
    logits = lm_head(params, x, cfg, compute_dtype)
    return logits, new_caches, aux


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def token_losses(logits, labels):
    """Per-token cross-entropy in float32.  logits: (B,S,V) or (B,S,C,V)."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if nll.ndim == 3:  # multi-codebook: mean over codebooks
        nll = nll.mean(-1)
    return nll  # (B,S)


def chunked_nll(params, hidden, labels, cfg: ModelConfig, *,
                compute_dtype=jnp.bfloat16, chunk: int = 512):
    """Fused cross-entropy: head matmul + CE per sequence chunk inside a
    rematerialized scan, so full (B,S,V) logits are never resident (the
    big-vocab memory fix; bwd recomputes each chunk's logits)."""
    b, s, d = hidden.shape
    c = min(chunk, s)
    nc = -(-s // c)
    pad = nc * c - s
    h = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0))) if pad else hidden
    lab_w = [(0, 0), (0, pad)] + [(0, 0)] * (labels.ndim - 2)
    lab = jnp.pad(labels, lab_w) if pad else labels
    h = h.reshape(b, nc, c, d).swapaxes(0, 1)          # (nc, B, c, d)
    lab = lab.reshape(b, nc, c, *labels.shape[2:]).swapaxes(0, 1)

    @jax.checkpoint
    def one(carry, xs):
        hc, lc = xs
        logits = lm_head(params, hc, cfg, compute_dtype)
        return carry, token_losses(logits, lc)

    _, nll = jax.lax.scan(one, (), (h, lab))
    nll = nll.swapaxes(0, 1).reshape(b, nc * c)
    return nll[:, :s]


def per_group_loss(params, batch, cfg: ModelConfig, *, num_groups: int,
                   compute_dtype=jnp.bfloat16, remat: bool = False):
    """Group-resolved LM loss for DRO.  batch needs "labels" (B,S[,ncb]) and
    "groups" (B,S) int32 in [0, num_groups).  Returns ((G,) losses, aux)."""
    hidden, _, aux = backbone(
        params, batch, cfg, mode="train", compute_dtype=compute_dtype, remat=remat)
    nll = chunked_nll(params, hidden, batch["labels"], cfg,
                      compute_dtype=compute_dtype)  # (B,S)
    g = batch["groups"]
    onehot = jax.nn.one_hot(g, num_groups, dtype=jnp.float32)  # (B,S,G)
    sums = jnp.einsum("bs,bsg->g", nll, onehot)
    counts = jnp.maximum(onehot.sum((0, 1)), 1.0)
    return sums / counts, aux


def lm_loss(params, batch, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
            remat: bool = False):
    hidden, _, aux = backbone(
        params, batch, cfg, mode="train", compute_dtype=compute_dtype, remat=remat)
    nll = chunked_nll(params, hidden, batch["labels"], cfg,
                      compute_dtype=compute_dtype)
    return nll.mean() + aux, aux


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _block_cache_shape(kind: str, cfg: ModelConfig, batch: int, seq_len: int,
                       dtype):
    hd = cfg.resolved_head_dim
    if kind == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nheads = d_in // s.d_head
        conv_ch = d_in + 2 * s.d_state
        return {
            "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
            "state": jnp.zeros((batch, nheads, s.d_head, s.d_state), jnp.float32),
        }
    if kind == "rglru":
        w = cfg.rglru.lru_width or cfg.d_model
        return {
            "conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, w), dtype),
            "h": jnp.zeros((batch, w), jnp.float32),
        }
    # attention-family: cache length = window if windowed else full seq
    window = tf._attn_window(kind, cfg)
    length = min(window, seq_len) if window else seq_len
    return {
        "k": jnp.zeros((batch, length, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, length, cfg.num_kv_heads, hd), dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    """Cache pytree mirroring the stacked segment structure."""
    caches = []
    for unit, reps in tf.segments(cfg):
        unit_caches = []
        for kind in unit:
            one = _block_cache_shape(kind, cfg, batch, seq_len, dtype)
            unit_caches.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (reps, *x.shape)), one))
        caches.append(tuple(unit_caches))
    return tuple(caches)


def decode_step(params, caches, tokens, pos, cfg: ModelConfig, *,
                compute_dtype=jnp.bfloat16):
    """One-token decode.  tokens: (B,1[,ncb]); pos: scalar int32 absolute
    position.  Returns (logits (B,1,V...), new_caches)."""
    logits, new_caches, _ = forward(
        params, {"tokens": tokens}, cfg, mode="decode",
        compute_dtype=compute_dtype, caches=caches, pos=pos)
    return logits, new_caches
