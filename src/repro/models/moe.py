"""Mixture-of-Experts MLP with top-k routing and capacity-bounded dense dispatch.

Dispatch uses the classic one-hot capacity formulation (Switch/GShard style):
deterministic shapes, compiles cleanly under GSPMD.  Expert weights carry a
leading experts dim; with ``moe_expert_parallel`` sharding (hillclimb option)
that dim maps onto the ``model`` mesh axis and dispatch lowers to all-to-alls.
Tokens overflowing an expert's capacity are dropped (residual passes through),
which matches the reference systems.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(key, cfg, d_model: int):
    m = cfg.moe
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, f = m.num_experts, m.expert_d_ff
    return {
        "router": dense_init(kr, (d_model, e), in_axis=0),
        "gate": dense_init(kg, (e, d_model, f), in_axis=1),
        "up": dense_init(ku, (e, d_model, f), in_axis=1),
        "down": dense_init(kd, (e, f, d_model), in_axis=1),
    }


def capacity(num_tokens: int, num_experts: int, top_k: int, factor: float = 1.25) -> int:
    return max(4, int(num_tokens * top_k / num_experts * factor))


def moe_mlp(params, x, cfg, compute_dtype=jnp.bfloat16):
    """x: (B, S, d).  Returns (out, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = m.num_experts, m.top_k
    # per-batch-row capacity keeps shapes batch-invariant
    cap = capacity(s, e, k, m.capacity_factor)

    xt = x.reshape(b, s, d)
    logits = jnp.einsum("bsd,de->bse", xt, params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (b,s,e)

    # top-k selection
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (b,s,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch): e * <frac_tokens_e, frac_prob_e>
    me = probs.mean(axis=(0, 1))  # (e,)
    one_hot_top1 = jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=(0, 1))
    aux = m.router_aux_coef * e * jnp.sum(me * ce)

    # Position of each (token, choice) within its expert's capacity buffer.
    sel = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)  # (b,s,k,e)
    flat_sel = sel.reshape(b, s * k, e)
    pos = jnp.cumsum(flat_sel, axis=1) * flat_sel - 1  # (b, s*k, e), -1 if unselected
    pos = pos.reshape(b, s, k, e)
    in_cap = (pos >= 0) & (pos < cap)

    # combine[b,s,k,e,c]: weight routing token (b,s) choice k to slot c of expert e
    combine = (
        gate_vals[..., None, None]
        * in_cap[..., None]
        * jax.nn.one_hot(jnp.clip(pos, 0, cap - 1), cap, dtype=jnp.float32)
        * sel[..., None].astype(jnp.float32)
    )
    combine = combine.sum(axis=2)  # (b,s,e,c)
    dispatch = (combine > 0).astype(compute_dtype)

    xe = jnp.einsum("bsec,bsd->becd", dispatch, xt.astype(compute_dtype))
    w = lambda p: p.astype(compute_dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, w(params["gate"])))
    h = h * jnp.einsum("becd,edf->becf", xe, w(params["up"]))
    ye = jnp.einsum("becf,efd->becd", h, w(params["down"]))
    out = jnp.einsum("bsec,becd->bsd", combine.astype(compute_dtype), ye)
    return out.astype(x.dtype), aux


def moe_mlp_sorted(params, x, cfg, compute_dtype=jnp.bfloat16):
    """Dropless sort-based dispatch (beyond-paper §Perf optimization).

    Flatten (token, choice) assignments, sort by expert, run grouped matmuls
    with ``jax.lax.ragged_dot`` (group_sizes = per-expert counts), unsort and
    combine.  Exactly N·k·d·f expert FLOPs — no capacity padding, no one-hot
    dispatch einsums (the dense path's dominant waste per §Roofline), and no
    token drops."""
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = m.num_experts, m.top_k
    xt = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xt, params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (n,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=0)
    ce = jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32).mean(axis=0)
    aux = m.router_aux_coef * e * jnp.sum(me * ce)

    flat_e = gate_idx.reshape(-1)                       # (n*k,) expert ids
    order = jnp.argsort(flat_e)                         # stable
    tok_of = order // k                                 # source token per slot
    xs = xt[tok_of].astype(compute_dtype)               # (n*k, d) sorted
    counts = jnp.bincount(flat_e, length=e)             # group sizes

    w = lambda p: p.astype(compute_dtype)
    h = jax.nn.silu(jax.lax.ragged_dot(xs, w(params["gate"]), counts))
    h = h * jax.lax.ragged_dot(xs, w(params["up"]), counts)
    ys = jax.lax.ragged_dot(h, w(params["down"]), counts)  # (n*k, d)

    gates_sorted = gate_vals.reshape(-1)[order].astype(jnp.float32)
    contrib = ys.astype(jnp.float32) * gates_sorted[:, None]
    out = jnp.zeros((n, d), jnp.float32).at[tok_of].add(contrib)
    return out.reshape(b, s, d).astype(x.dtype), aux
