"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    r_t = sigmoid(W_a x_t)          (recurrence gate)
    i_t = sigmoid(W_x x_t)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

computed chunk-wise: lax.scan over chunks, associative scan within a chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

RGLRU_C = 8.0


def init_rglru(key, cfg, d_model: int):
    r = cfg.rglru
    w = r.lru_width or d_model
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "in_x": dense_init(k1, (d_model, w), in_axis=0),
        "in_gate": dense_init(k2, (d_model, w), in_axis=0),
        "conv_w": dense_init(k3, (r.conv_width, w), in_axis=0) * 0.1,
        "conv_b": jnp.zeros((w,)),
        "wa": dense_init(k4, (w, w), in_axis=0),
        "ba": jnp.zeros((w,)),
        "wx": dense_init(k5, (w, w), in_axis=0),
        "bx": jnp.zeros((w,)),
        # softplus(lambda) ~ 0.2..0.99 decay range init
        "lam": jnp.linspace(0.5, 4.0, w),
        "out": dense_init(k6, (w, d_model), in_axis=0),
    }


def _conv1d(x, w, b, state=None):
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k)) + b
    return y, (xp[:, -(k - 1) :, :] if k > 1 else None)


def rglru_scan(a, u, h0=None, chunk: int = 256):
    """Linear recurrence h_t = a_t h_{t-1} + u_t.  a,u: (B,S,W) float32."""
    b, s, w = a.shape
    l = min(chunk, s)
    nc = -(-s // l)
    pad = nc * l - s
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
    a = a.reshape(b, nc, l, w).transpose(1, 0, 2, 3)
    u = u.reshape(b, nc, l, w).transpose(1, 0, 2, 3)
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)

    def combine(x, y):
        (ax, ux), (ay, uy) = x, y
        return ax * ay, ay * ux + uy

    def step(h, inp):
        ac, uc = inp
        # prepend the carry as an initial element
        a_all, u_all = combine((jnp.ones_like(ac[:, :1]), h[:, None]),
                               (ac[:, :1], uc[:, :1]))
        a0 = jnp.concatenate([a_all, ac[:, 1:]], axis=1)
        u0 = jnp.concatenate([u_all, uc[:, 1:]], axis=1)
        _, hs = jax.lax.associative_scan(combine, (a0, u0), axis=1)
        return hs[:, -1], hs

    h_fin, ys = jax.lax.scan(step, h0, (a, u))
    h = ys.transpose(1, 0, 2, 3).reshape(b, nc * l, w)
    return h[:, :s], h_fin


def rglru_forward(params, x, cfg, compute_dtype=jnp.bfloat16, conv_state=None,
                  h_state=None, decode: bool = False):
    """RG-LRU block.  x: (B,S,d).  Returns (out, cache)."""
    w_ = lambda p: p.astype(compute_dtype)
    xb = x @ w_(params["in_x"])
    gate = jax.nn.gelu(x @ w_(params["in_gate"]))
    xb, new_conv = _conv1d(xb, w_(params["conv_w"]), w_(params["conv_b"]), conv_state)

    xf = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["wa"] + params["ba"])
    i = jax.nn.sigmoid(xf @ params["wx"] + params["bx"])
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    u = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * xf)

    if decode:
        h0 = h_state if h_state is not None else jnp.zeros(
            (x.shape[0], xb.shape[-1]), jnp.float32)
        h_new = a[:, 0] * h0 + u[:, 0]
        h = h_new[:, None]
        h_fin = h_new
    else:
        h, h_fin = rglru_scan(a, u, h_state)

    y = h.astype(compute_dtype) * gate
    out = y @ w_(params["out"])
    return out.astype(x.dtype), {"conv": new_conv, "h": h_fin}
