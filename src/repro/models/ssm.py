"""Mamba2 (SSD — state-space duality) block, pure-jnp chunked implementation.

The recurrence per head h with state S in R^{P x N}:
    S_t = a_t * S_{t-1} + (dt_t * x_t) outer B_t          a_t = exp(A_h * dt_t)
    y_t = C_t . S_t + D_h * x_t
evaluated chunk-parallel (intra-chunk matmul form + inter-chunk scan), exactly
the SSD algorithm of arXiv:2405.21060 — which is also the structure the Pallas
kernel (`repro.kernels.ssd_scan`) tiles for VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm


def init_ssm(key, cfg, d_model: int):
    s = cfg.ssm
    d_in = s.expand * d_model
    nheads = d_in // s.d_head
    conv_ch = d_in + 2 * s.d_state
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # projections: z (gate), x, B, C, dt
        "in_proj": dense_init(k1, (d_model, 2 * d_in + 2 * s.d_state + nheads), in_axis=0),
        "conv_w": dense_init(k2, (s.d_conv, conv_ch), in_axis=0) * 0.1,
        "conv_b": jnp.zeros((conv_ch,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)),
        "D": jnp.ones((nheads,)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nheads,), 0.01))),  # softplus^-1
        "norm": jnp.zeros((d_in,)),
        "out_proj": dense_init(k3, (d_in, d_model), in_axis=0),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv.  x: (B,S,C); w: (K,C).  state: (B,K-1,C) carry
    (decode).  Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1) :, :] if k > 1 else None
    return jax.nn.silu(y), new_state


def ssd_chunked(xdt, loga, Bm, Cm, chunk: int, state0=None):
    """Chunk-parallel SSD scan.

    xdt:  (B,S,H,P)  inputs pre-multiplied by dt
    loga: (B,S,H)    log decay per token/head
    Bm,Cm:(B,S,N)    input/output projections (single group)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    b, s, h, p = xdt.shape
    n = Bm.shape[-1]
    l = min(chunk, s)
    nc = -(-s // l)
    pad = nc * l - s
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    xdt = xdt.reshape(b, nc, l, h, p).transpose(1, 0, 2, 3, 4)
    loga = loga.reshape(b, nc, l, h).transpose(1, 0, 2, 3)
    Bm = Bm.reshape(b, nc, l, n).transpose(1, 0, 2, 3)
    Cm = Cm.reshape(b, nc, l, n).transpose(1, 0, 2, 3)

    if state0 is None:
        state0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, inp):
        xc, lac, bc, cc = inp  # (B,l,H,P), (B,l,H), (B,l,N), (B,l,N)
        cum = jnp.cumsum(lac.astype(jnp.float32), axis=1)  # (B,l,H) inclusive
        # intra-chunk: scores[t,u] = exp(cum_t - cum_u) * (C_t . B_u) * [u <= t]
        rel = cum[:, :, None, :] - cum[:, None, :, :]  # (B,t,u,H)
        maskv = jnp.tril(jnp.ones((l, l), bool))[None, :, :, None]
        decay = jnp.where(maskv, jnp.exp(rel), 0.0)
        cb = jnp.einsum("btn,bun->btu", cc.astype(jnp.float32), bc.astype(jnp.float32))
        scores = decay * cb[:, :, :, None]  # (B,t,u,H)
        y_intra = jnp.einsum("btuh,buhp->bthp", scores, xc.astype(jnp.float32))
        # inter-chunk contribution from carried state
        y_inter = jnp.einsum(
            "btn,bhpn,bth->bthp", cc.astype(jnp.float32), state, jnp.exp(cum)
        )
        # chunk state update
        last = cum[:, -1:, :]  # (B,1,H)
        dec_to_end = jnp.exp(last - cum)  # (B,l,H)
        s_chunk = jnp.einsum(
            "blh,blhp,bln->bhpn", dec_to_end, xc.astype(jnp.float32),
            bc.astype(jnp.float32),
        )
        new_state = jnp.exp(last[:, 0, :])[:, :, None, None] * state + s_chunk
        return new_state, (y_intra + y_inter).astype(xdt.dtype)

    final, ys = jax.lax.scan(step, state0, (xdt, loga, Bm, Cm))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * l, h, p)
    return y[:, :s], final


def ssm_forward(params, x, cfg, compute_dtype=jnp.bfloat16, conv_state=None,
                ssd_state=None, decode: bool = False):
    """Mamba2 block.  x: (B,S,d).  Returns (out, new_cache | None)."""
    s = cfg.ssm
    d = x.shape[-1]
    d_in = s.expand * d
    nheads = d_in // s.d_head
    n = s.d_state
    w = lambda p: p.astype(compute_dtype)

    proj = x @ w(params["in_proj"])
    z, xb, Bm, Cm, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xb, Bm, Cm], axis=-1)
    conv_out, new_conv = _causal_conv(
        conv_in, w(params["conv_w"]), w(params["conv_b"]), conv_state
    )
    xb, Bm, Cm = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["A_log"])  # (H,)
    loga = a * dt  # (B,S,H)
    xh = xb.reshape(*xb.shape[:-1], nheads, s.d_head)
    xdt = xh.astype(jnp.float32) * dt[..., None]

    if decode:
        # single step: S <- exp(loga) S + xdt outer B ; y = C . S
        state = ssd_state if ssd_state is not None else jnp.zeros(
            (x.shape[0], nheads, s.d_head, n), jnp.float32
        )
        aa = jnp.exp(loga[:, 0])  # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", xdt[:, 0], Bm[:, 0].astype(jnp.float32))
        state = aa[..., None, None] * state + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), state)[:, None]
        new_ssd = state
    else:
        y, new_ssd = ssd_chunked(xdt, loga, Bm.astype(jnp.float32),
                                 Cm.astype(jnp.float32), s.chunk, ssd_state)

    y = y + params["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(*y.shape[:-2], d_in).astype(compute_dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    out = y @ w(params["out_proj"])
    cache = {"conv": new_conv, "state": new_ssd}
    return out.astype(x.dtype), cache
