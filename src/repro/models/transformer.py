"""Composable decoder stack.

A model is a sequence of *blocks* (see ``ModelConfig.blocks()``).  Layers are
grouped into repeated *units* (the arch's block pattern) whose parameters are
stacked along a leading repeat dim and executed with ``lax.scan`` — keeping the
HLO O(pattern) instead of O(layers) for 80-layer configs.  A remainder segment
(when num_layers % pattern != 0) is its own smaller stack.

Block kinds:
  attn / sliding         GQA attention (+ optional window) + SwiGLU MLP
  attn_local             windowed attention (RecurrentGemma local layer) + MLP
  moe                    GQA attention + MoE MLP
  ssm                    Mamba2 SSD mixer (norm + mixer residual only)
  rglru                  RG-LRU temporal mixer + MLP
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import context as dist_ctx
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import init_mlp, mlp, rms_norm

Cache = Any


# ---------------------------------------------------------------------------
# Segments
# ---------------------------------------------------------------------------

def segments(cfg: ModelConfig) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
    """((unit_kinds, n_repeats), ...) covering cfg.blocks()."""
    blocks = cfg.blocks()
    pat = cfg.block_pattern or None
    if pat is None:
        if cfg.arch_type == "hybrid":
            pat = cfg.rglru.block_pattern
        elif cfg.arch_type == "moe":
            pat = ("moe",)
        elif cfg.arch_type == "ssm":
            pat = ("ssm",)
        else:
            pat = (blocks[0],)
    n_full = len(blocks) // len(pat)
    rem = blocks[n_full * len(pat):]
    segs = []
    if n_full:
        segs.append((tuple(pat), n_full))
    if rem:
        segs.append((tuple(rem), 1))
    return tuple(segs)


# ---------------------------------------------------------------------------
# Per-block init / forward
# ---------------------------------------------------------------------------

def init_block(key, kind: str, cfg: ModelConfig):
    d = cfg.d_model
    keys = jax.random.split(key, 4)
    if kind == "ssm":
        return {"norm1": jnp.zeros((d,)), "ssm": ssm_lib.init_ssm(keys[0], cfg, d)}
    if kind == "rglru":
        return {
            "norm1": jnp.zeros((d,)),
            "rglru": rglru_lib.init_rglru(keys[0], cfg, d),
            "norm2": jnp.zeros((d,)),
            "mlp": init_mlp(keys[1], d, cfg.d_ff),
        }
    p = {
        "norm1": jnp.zeros((d,)),
        "attn": attn_lib.init_attention(keys[0], cfg, d),
        "norm2": jnp.zeros((d,)),
    }
    if kind == "moe":
        p["moe"] = moe_lib.init_moe(keys[1], cfg, d)
    else:
        p["mlp"] = init_mlp(keys[1], d, cfg.d_ff)
    return p


def _attn_window(kind: str, cfg: ModelConfig) -> int:
    if kind == "sliding":
        return cfg.sliding_window
    if kind == "attn_local":
        return cfg.rglru.local_window
    if cfg.long_context_window:  # long_500k variant for full-attn archs
        return cfg.long_context_window
    return 0


def block_forward(
    kind: str,
    params,
    x,
    cfg: ModelConfig,
    *,
    mode: str,            # "train" | "prefill" | "decode"
    positions,            # (B,S) absolute positions
    cache: Optional[Dict] = None,
    pos=None,             # scalar decode position
    compute_dtype=jnp.bfloat16,
    attn_impl: str = "auto",
):
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, params["norm1"], cfg.norm_eps)

    if kind == "ssm":
        conv_s = cache["conv"] if cache else None
        ssd_s = cache["state"] if cache else None
        y, new_cache = ssm_lib.ssm_forward(
            params["ssm"], h, cfg, compute_dtype, conv_s, ssd_s,
            decode=(mode == "decode"),
        )
        return x + y, new_cache, aux

    if kind == "rglru":
        conv_s = cache["conv"] if cache else None
        h_s = cache["h"] if cache else None
        y, new_cache = rglru_lib.rglru_forward(
            params["rglru"], h, cfg, compute_dtype, conv_s, h_s,
            decode=(mode == "decode"),
        )
        x = x + y
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        x = x + mlp(params["mlp"], h2, compute_dtype)
        return x, new_cache, aux

    # attention-family blocks -------------------------------------------------
    window = _attn_window(kind, cfg)
    q, k, v = attn_lib.qkv_project(params["attn"], h, cfg, positions, compute_dtype)
    q = dist_ctx.apply("attn_qkv", q)  # optional head-sharding switch

    if mode == "decode":
        assert cache is not None
        kc, vc = cache["k"], cache["v"]
        c_len = kc.shape[1]
        # write position: ring for windowed caches, absolute otherwise.
        # One-hot masked write instead of dynamic-update-slice: elementwise
        # select preserves a seq-sharded cache layout under GSPMD (a DUS on a
        # sharded dim triggers involuntary full rematerialization).
        widx = (pos % c_len) if window else jnp.minimum(pos, c_len - 1)
        onehot = (jnp.arange(c_len, dtype=jnp.int32) == widx)[None, :, None, None]
        kc = jnp.where(onehot, k.astype(kc.dtype), kc)
        vc = jnp.where(onehot, v.astype(vc.dtype), vc)
        # cold-start validity: slots <= pos written so far (ring: all-true
        # once pos >= window, which is exactly when wrapping starts)
        valid = jnp.arange(c_len, dtype=jnp.int32) <= pos
        ctx = attn_lib.decode_attention(q, kc.astype(compute_dtype),
                                        vc.astype(compute_dtype), valid)
        new_cache = {"k": kc, "v": vc}
    else:
        new_cache = None
        if mode == "prefill" or attn_impl == "qchunk":
            ctx = attn_lib.qchunk_attention(q, k, v, window=window)
        else:
            ctx = attn_lib.naive_attention(q, k, v, window=window)
        if cache is not None:  # prefill populating a cache
            c_len = cache["k"].shape[1]
            kw = k[:, -c_len:].astype(cache["k"].dtype)
            vw = v[:, -c_len:].astype(cache["v"].dtype)
            new_cache = {"k": kw, "v": vw}

    ctx = dist_ctx.apply("attn_out", ctx)  # back to seq-sharding
    y = attn_lib.out_project(params["attn"], ctx, compute_dtype)
    x = x + y

    h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
    if kind == "moe":
        moe_fn = (moe_lib.moe_mlp_sorted if cfg.moe.dispatch == "sorted"
                  else moe_lib.moe_mlp)
        y2, aux = moe_fn(params["moe"], h2, cfg, compute_dtype)
    else:
        y2 = mlp(params["mlp"], h2, compute_dtype)
    return x + y2, new_cache, aux


# ---------------------------------------------------------------------------
# Stacked segments
# ---------------------------------------------------------------------------

def init_stack(key, cfg: ModelConfig):
    """Params: tuple of segment stacks; each stack is a tuple (per block in
    unit) of param pytrees stacked along a leading repeat dim."""
    segs = segments(cfg)
    out = []
    for si, (unit, reps) in enumerate(segs):
        unit_stacks = []
        for bi, kind in enumerate(unit):
            ks = jax.random.split(jax.random.fold_in(key, si * 97 + bi), reps)
            ps = [init_block(ks[r], kind, cfg) for r in range(reps)]
            unit_stacks.append(jax.tree.map(lambda *a: jnp.stack(a), *ps))
        out.append(tuple(unit_stacks))
    return tuple(out)


def stack_forward(
    stack_params,
    x,
    cfg: ModelConfig,
    *,
    mode: str,
    positions,
    caches=None,
    pos=None,
    compute_dtype=jnp.bfloat16,
    attn_impl: str = "auto",
    remat: bool = False,
):
    """Run all segments.  caches mirrors stack_params structure (or None).
    Returns (x, new_caches, total_aux)."""
    segs = segments(cfg)
    new_caches = []
    total_aux = jnp.zeros((), jnp.float32)

    for si, (unit, reps) in enumerate(segs):
        seg_params = stack_params[si]
        seg_cache = caches[si] if caches is not None else None

        def unit_fn(carry, xs, unit=unit):
            xx, aux = carry
            p_slices, c_slices = xs
            new_cs = []
            for bi, kind in enumerate(unit):
                c = c_slices[bi] if c_slices is not None else None
                xx, nc, a = block_forward(
                    kind, p_slices[bi], xx, cfg, mode=mode, positions=positions,
                    cache=c, pos=pos, compute_dtype=compute_dtype,
                    attn_impl=attn_impl,
                )
                new_cs.append(nc)
            xx = dist_ctx.apply_residual(xx)
            return (xx, aux + a), tuple(new_cs)

        f = jax.checkpoint(unit_fn) if (remat and mode == "train") else unit_fn
        xs = (seg_params, seg_cache)
        (x, total_aux), seg_new_cache = jax.lax.scan(f, (x, total_aux), xs)
        new_caches.append(seg_new_cache)

    return x, (tuple(new_caches) if caches is not None else None), total_aux
