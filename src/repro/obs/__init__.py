"""Structured telemetry: spans, counters/gauges, the communication ledger,
and opt-in profiler capture (see ``docs/architecture.md``, "Observability").

``events``   — the event model: ``Telemetry`` + pluggable sinks (memory,
               JSONL file, stderr).
``ledger``   — analytic per-round communication accounting (bytes on the
               wire + collective counts) per gossip lowering.
``profiler`` — ``jax.profiler`` Perfetto capture windows + algorithm-health
               gauges sampled at chunk boundaries.
``report``   — ``python -m repro.obs.report run.jsonl``: fold a run's JSONL
               into a time/communication/convergence summary.

Everything here is host-side and strictly opt-in: a run that does not
construct a sink dispatches nothing extra and its trajectory is
bit-identical to a run that never imported this package
(tests/test_obs.py pins that).
"""
from repro.obs.events import (  # noqa: F401
    EVENT_TYPES,
    NULL,
    TELEMETRY_VERSION,
    JsonlSink,
    MemorySink,
    StderrSink,
    Telemetry,
)
from repro.obs.ledger import (  # noqa: F401
    LEDGER_VERSION,
    CommLedger,
    RoundComm,
    ledger_for_state,
    links_per_gossip,
    round_comm,
)
from repro.obs.profiler import (  # noqa: F401
    Profiler,
    health_gauges,
)
