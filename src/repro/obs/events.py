"""Telemetry events: span timers, counters/gauges, and pluggable sinks.

An *event* is one flat JSON-able dict.  Every event carries:

* ``v``    — the schema version (:data:`TELEMETRY_VERSION`);
* ``type`` — ``"span" | "counter" | "gauge" | "metrics" | "ledger" | "meta"``;
* ``t``    — wall-clock unix seconds at emit time.

Type-specific fields:

* ``span``    — ``name`` + ``dur_s`` (monotonic-clock duration; extra
  attributes ride alongside, e.g. ``round``/``length`` for a chunk
  dispatch).  Spans come from the ``with telemetry.span("dispatch"): …``
  context manager or, for durations measured elsewhere (XLA compile time
  accumulated by ``engine.timed_chunk_builder``), from
  :meth:`Telemetry.span_event`.
* ``counter`` — ``name`` + ``value`` (a monotonically accumulated quantity:
  bytes communicated, rounds executed).
* ``gauge``   — ``name`` + ``value`` (a point-in-time sample: Σc drift,
  consensus error, EF residual norm).
* ``metrics`` — one engine history record verbatim (``round`` + the metric
  columns + the ``wall_s/compile_s/run_s`` stamps).
* ``ledger``  — a communication-ledger update (``repro.obs.ledger``).
* ``meta``    — one-shot run description (config summary, versions).

Sinks are deliberately dumb: ``emit(event)`` and optional ``close()``.
``Telemetry`` fans one event out to every sink.  A ``Telemetry`` with no
sinks is *disabled*: every method is a cheap no-op (``span`` returns a
shared null context manager without touching the clock), which is what the
zero-overhead guarantee rides on.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

TELEMETRY_VERSION = 1

EVENT_TYPES = ("span", "counter", "gauge", "metrics", "ledger", "meta")


class MemorySink:
    """Collects events in a list — tests and in-process consumers."""

    def __init__(self) -> None:
        self.events: List[dict] = []

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def close(self) -> None:
        pass


class JsonlSink:
    """One JSON object per line, append-mode, flushed per event.

    The file is opened lazily on the first event, so constructing a sink
    (e.g. from a CLI flag) touches nothing until telemetry actually flows.
    Values that are not JSON-native (numpy scalars, jax arrays) go through
    ``float()``/``str()`` fallbacks — the sink never raises mid-run.
    """

    def __init__(self, path: str) -> None:
        self.path = path
        self._fh = None

    @staticmethod
    def _default(obj: Any):
        try:
            return float(obj)
        except (TypeError, ValueError):
            return str(obj)

    def emit(self, event: dict) -> None:
        if self._fh is None:
            self._fh = open(self.path, "a")
        self._fh.write(json.dumps(event, default=self._default) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class StderrSink:
    """Human-readable console stream.

    ``formatter(event) -> str | None`` picks the representation; ``None``
    drops the event from the console (the JSONL sink still records it).
    The default formatter renders every event type one-per-line.
    """

    def __init__(self,
                 formatter: Optional[Callable[[dict], Optional[str]]] = None,
                 stream=None) -> None:
        self.formatter = formatter or self._default_format
        self.stream = stream

    @staticmethod
    def _default_format(event: dict) -> Optional[str]:
        etype = event.get("type", "?")
        skip = {"v", "type", "t", "name", "dur_s", "value"}
        attrs = " ".join(f"{k}={event[k]}" for k in event if k not in skip)
        if etype == "span":
            return (f"[obs] span {event.get('name')} "
                    f"{event.get('dur_s', 0):.3f}s {attrs}".rstrip())
        if etype in ("counter", "gauge"):
            return (f"[obs] {etype} {event.get('name')}="
                    f"{event.get('value')} {attrs}".rstrip())
        return f"[obs] {etype} {attrs}".rstrip()

    def emit(self, event: dict) -> None:
        line = self.formatter(event)
        if line is None:
            return
        print(line, file=self.stream or sys.stderr, flush=True)

    def close(self) -> None:
        pass


class _NullSpan:
    """Shared no-op context manager: the disabled-telemetry span."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    def __init__(self, telemetry: "Telemetry", name: str, attrs: dict) -> None:
        self._telemetry = telemetry
        self._name = name
        self._attrs = attrs
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        dur = time.monotonic() - self._t0
        self._telemetry.span_event(self._name, dur, **self._attrs)
        return False


class Telemetry:
    """Fans events out to sinks; a sink-less instance is a no-op."""

    def __init__(self, sinks: Sequence[Any] = ()) -> None:
        self.sinks = list(sinks)

    @property
    def enabled(self) -> bool:
        return bool(self.sinks)

    def emit(self, event: Dict[str, Any]) -> None:
        if not self.sinks:
            return
        event = dict(event)
        event.setdefault("v", TELEMETRY_VERSION)
        event.setdefault("t", time.time())
        for sink in self.sinks:
            sink.emit(event)

    def span(self, name: str, **attrs):
        """``with telemetry.span("dispatch", round=r): …`` — emits a span
        event with the monotonic-clock duration on exit."""
        if not self.sinks:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def span_event(self, name: str, dur_s: float, **attrs) -> None:
        """A span whose duration was measured elsewhere (e.g. the AOT
        compile seconds ``engine.timed_chunk_builder`` accumulates)."""
        self.emit({"type": "span", "name": name,
                   "dur_s": round(float(dur_s), 6), **attrs})

    def counter(self, name: str, value, **attrs) -> None:
        self.emit({"type": "counter", "name": name, "value": value, **attrs})

    def gauge(self, name: str, value, **attrs) -> None:
        self.emit({"type": "gauge", "name": name, "value": float(value),
                   **attrs})

    def metrics(self, record: dict) -> None:
        """One engine history record as a ``metrics`` event, verbatim."""
        if not self.sinks:
            return
        self.emit({"type": "metrics", **record})

    def meta(self, name: str, **fields) -> None:
        self.emit({"type": "meta", "name": name,
                   "telemetry_version": TELEMETRY_VERSION, **fields})

    def close(self) -> None:
        for sink in self.sinks:
            close = getattr(sink, "close", None)
            if close is not None:
                close()


#: The shared disabled instance — pass where a telemetry object is required
#: but nothing should be recorded.
NULL = Telemetry(())
