"""The communication ledger: analytic bytes-on-the-wire per round.

K-GT-Minimax's headline claim is *communication efficiency* — convergence
per communication round, per byte moved.  This module computes, from the
configured lowering alone (no tracing, no device work), what one round of
Algorithm 1 puts on the wire, so every train/sweep run can report the
paper's efficiency metric as a first-class quantity.

The model
---------

One round gossips, per variable v ∈ {x, y} with packed payload ``D_v``
elements per client:

* with gradient tracking (``kgt_minimax``/``gt_gda``) on a packed or robust
  lowering — **two quantities**: the round delta Δ (lines 7–8) and the
  parameters θ (lines 10–11);
* the per-leaf lowerings (``dense``/``ring``/``fused_*``) always move both
  (the fused_* variants halve the collective *launches*, not the bytes);
* without tracking on a packed lowering — **one quantity**: the pre-stepped
  ``θ + η_s·Δ``.

How many values cross the wire per gossip is the *links* count ``L``
(receives summed over clients):

* dense-family lowerings (``dense``/``fused_dense``/``pallas_packed``/
  ``fused_round``/dense robust) all-gather the full client axis:
  ``L = n·(n−1)``;
* ``ring``/``fused_ring`` exchange with the two ring neighbors:
  ``L = 2n`` (``n`` for n=2, 0 for n=1);
* ``sparse_*`` lowerings gather neighbor rows through the padded-CSR
  support: ``L = Σ_i deg_i`` (the directed edge count of the topology).

Bytes per transmitted element come from ``gossip_dtype`` (f32 = 4,
bf16 = 2); with ``gossip_compress`` the Δ-gossip narrows to the quantizer's
wire width (``kernels.quantize.wire_bits``: bf16 = 2 bytes, int8 = 1 byte
**plus one f32 scale per row per link** — the per-client scale travels with
the codes).  The θ-gossip stays at ``gossip_dtype``; compression applies to
the transmitted delta only (see ``repro.core.compression``).

For per-round *random* topologies (churn families) the ledger accounts the
static support graph — an exact figure for ``static``/``dropout`` upper
bounds and the support-level cost for ER/pairwise draws.

Everything is exact integer arithmetic on host ints; a
:class:`CommLedger` accumulates rounds into totals and renders ledger
events for the telemetry stream.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

LEDGER_VERSION = 1

# lowerings whose gossip collective touches the full client axis
_DENSE_FAMILY = ("dense", "fused_dense", "pallas_packed", "fused_round",
                 "coord_median", "trimmed_mean")
_RING_FAMILY = ("ring", "fused_ring")
_SPARSE_FAMILY = ("sparse_packed", "sparse_coord_median",
                  "sparse_trimmed_mean")
_PER_LEAF = ("dense", "ring", "fused_dense", "fused_ring")
_TRACKING_ALGOS = ("kgt_minimax", "gt_gda")


def _dtype_bytes(gossip_dtype: Optional[str]) -> int:
    return int(np.dtype(gossip_dtype or "float32").itemsize)


def _compress_bytes(method: Optional[str]) -> Tuple[Optional[int], int]:
    """(payload bytes per element, extra bytes per row) for the compressed
    Δ-gossip; (None, 0) when compression is off."""
    if method in (None, "none", ""):
        return None, 0
    from repro.kernels.quantize import QUANT_METHODS, wire_bits

    if method not in QUANT_METHODS:
        raise ValueError(f"unknown gossip_compress {method!r}: {QUANT_METHODS}")
    # int8 ships one f32 scale per (client-)row alongside the codes
    return wire_bits(method) // 8, 4 if method == "int8" else 0


def links_per_gossip(mixing_impl: str, n: int, *, topology: str = "ring",
                     edges: Optional[int] = None) -> int:
    """Values received per gossip, summed over clients, for the lowering."""
    if mixing_impl in _DENSE_FAMILY:
        return n * (n - 1)
    if mixing_impl in _RING_FAMILY:
        if n <= 1:
            return 0
        return n if n == 2 else 2 * n
    if mixing_impl in _SPARSE_FAMILY:
        if edges is None:
            from repro.core import sparse_topology as sparse_lib

            edges = sparse_lib.sparse_mixing_matrix(topology, n).num_edges
        return int(edges)
    raise ValueError(f"unknown mixing_impl {mixing_impl!r} for the ledger")


def _quantities(mixing_impl: str, track: bool) -> int:
    """Gossiped quantities per variable per round (see module docstring)."""
    if mixing_impl in _PER_LEAF:
        return 2  # the generic path mixes Δ and θ regardless of tracking
    return 2 if track else 1


def _collectives(mixing_impl: str, track: bool,
                 leaves: Sequence[int]) -> int:
    """Collective launches per round.

    Per-leaf lowerings issue one collective per leaf per gossiped quantity
    (``fused_*`` pack Δ and θ into one launch); the packed lowerings fuse
    the whole per-variable epilogue into one launch each; ``fused_round``
    runs the entire round — both variables — as a single kernel pass.
    """
    num_vars = len(leaves)
    if mixing_impl in ("dense", "ring"):
        return 2 * sum(leaves)
    if mixing_impl in ("fused_dense", "fused_ring"):
        return sum(leaves)
    if mixing_impl == "fused_round":
        return 1
    if mixing_impl in ("pallas_packed", "sparse_packed"):
        return num_vars
    if mixing_impl in ("coord_median", "trimmed_mean",
                       "sparse_coord_median", "sparse_trimmed_mean"):
        # the robust epilogue aggregates θ+η_s·Δ and (tracking) Δ per var
        return (2 if track else 1) * num_vars
    raise ValueError(f"unknown mixing_impl {mixing_impl!r} for the ledger")


@dataclasses.dataclass(frozen=True)
class RoundComm:
    """What one round moves, analytically, for a configured lowering."""
    mixing_impl: str
    n: int
    dims: Tuple[int, ...]          # packed payload per variable (D_x, D_y)
    links: int                     # values received per gossip, all clients
    quantities: int                # gossiped quantities per variable
    elems_per_round: int           # payload elements on the wire per round
    bytes_per_round: int
    collectives_per_round: int
    gossip_dtype: str = "float32"
    gossip_compress: Optional[str] = None

    def describe(self) -> dict:
        """JSON-able summary for meta events / provenance stamps."""
        return {
            "ledger_version": LEDGER_VERSION,
            "mixing_impl": self.mixing_impl,
            "n": self.n,
            "dims": list(self.dims),
            "links": self.links,
            "quantities": self.quantities,
            "elems_per_round": self.elems_per_round,
            "bytes_per_round": self.bytes_per_round,
            "collectives_per_round": self.collectives_per_round,
            "gossip_dtype": self.gossip_dtype,
            "gossip_compress": self.gossip_compress,
        }


def round_comm(
    *,
    mixing_impl: str,
    n: int,
    dims: Sequence[int],
    leaves: Optional[Sequence[int]] = None,
    topology: str = "ring",
    edges: Optional[int] = None,
    track: bool = True,
    gossip_dtype: Optional[str] = "float32",
    gossip_compress: Optional[str] = None,
) -> RoundComm:
    """Build the per-round communication model for one configuration.

    ``dims`` — packed payload elements per client per variable (``(D_x,
    D_y)`` for the minimax state); ``leaves`` — leaf counts per variable
    (defaults to one leaf each, the packed view); ``edges`` — directed edge
    count for sparse lowerings (derived from ``topology`` when omitted);
    ``track`` — whether the algorithm carries gradient-tracking corrections.
    """
    dims = tuple(int(d) for d in dims)
    leaves = tuple(int(l) for l in (leaves if leaves is not None
                                    else (1,) * len(dims)))
    if len(leaves) != len(dims):
        raise ValueError(f"dims {dims} and leaves {leaves} must be parallel")
    links = links_per_gossip(mixing_impl, n, topology=topology, edges=edges)
    quantities = _quantities(mixing_impl, track)
    theta_b = _dtype_bytes(gossip_dtype)
    comp_b, comp_row_b = _compress_bytes(gossip_compress)
    total_d = sum(dims)
    elems = links * total_d * quantities
    if quantities == 2:
        theta_bytes = links * total_d * theta_b
        if comp_b is not None:
            delta_bytes = links * (total_d * comp_b
                                   + comp_row_b * len(dims))
        else:
            delta_bytes = links * total_d * theta_b
        total_bytes = theta_bytes + delta_bytes
    else:
        # single pre-stepped gossip θ + η_s·Δ at the gossip dtype
        total_bytes = links * total_d * theta_b
    return RoundComm(
        mixing_impl=mixing_impl, n=n, dims=dims, links=links,
        quantities=quantities, elems_per_round=elems,
        bytes_per_round=int(total_bytes),
        collectives_per_round=_collectives(mixing_impl, track, leaves),
        gossip_dtype=str(gossip_dtype or "float32"),
        gossip_compress=(None if gossip_compress in (None, "none", "")
                         else gossip_compress))


def ledger_for_state(cfg, state) -> "CommLedger":
    """A :class:`CommLedger` for an ``AlgorithmConfig`` + ``KGTState`` pair —
    payload dims from the packed specs, leaf counts from the trees."""
    import jax

    from repro.core import packing

    dims = (packing.pack_spec(state.x).dim, packing.pack_spec(state.y).dim)
    leaves = (len(jax.tree.leaves(state.x)), len(jax.tree.leaves(state.y)))
    return CommLedger(round_comm(
        mixing_impl=cfg.mixing_impl, n=cfg.num_clients, dims=dims,
        leaves=leaves, topology=cfg.topology,
        track=cfg.algorithm in _TRACKING_ALGOS,
        gossip_dtype=cfg.gossip_dtype,
        gossip_compress=getattr(cfg, "gossip_compress", None)))


class CommLedger:
    """Accumulates :class:`RoundComm` over executed rounds."""

    def __init__(self, comm: RoundComm) -> None:
        self.comm = comm
        self.rounds = 0

    @property
    def bytes_per_round(self) -> int:
        return self.comm.bytes_per_round

    @property
    def total_bytes(self) -> int:
        return self.rounds * self.comm.bytes_per_round

    @property
    def total_collectives(self) -> int:
        return self.rounds * self.comm.collectives_per_round

    def add_rounds(self, k: int) -> None:
        self.rounds += int(k)

    def describe(self) -> dict:
        return self.comm.describe()

    def event(self, *, rounds: Optional[int] = None, **attrs) -> dict:
        """A ``ledger`` telemetry event: the increment (``rounds``/``bytes``)
        plus the running totals."""
        out = {
            "type": "ledger",
            "ledger_version": LEDGER_VERSION,
            "mixing_impl": self.comm.mixing_impl,
            "bytes_per_round": self.comm.bytes_per_round,
            "collectives_per_round": self.comm.collectives_per_round,
            "rounds_total": self.rounds,
            "bytes_total": self.total_bytes,
            "collectives_total": self.total_collectives,
        }
        if rounds is not None:
            out["rounds"] = int(rounds)
            out["bytes"] = int(rounds) * self.comm.bytes_per_round
        out.update(attrs)
        return out
