"""Opt-in profiler capture + algorithm-health gauges.

:class:`Profiler` wraps ``jax.profiler``'s Perfetto trace capture behind an
N-round window: ``start()`` before ``engine.run`` opens the trace, and the
profiler's chunk-boundary hook closes it once the requested number of
rounds has executed (0 = the whole run, closed by ``stop()``/context exit).
The trace lands under ``directory`` and opens in Perfetto / TensorBoard.

:func:`health_gauges` samples the algorithm-health quantities the theory
says to watch — host-side, from the state at a chunk boundary, so they cost
a handful of tiny reductions **only when telemetry is on**:

* ``corr_x_drift`` / ``corr_y_drift`` — ‖c̄‖ for both corrections (Lemma 8
  says exactly 0 for the tracking variants; drift means the correction
  update is wrong);
* ``consensus_x`` / ``consensus_y`` — the client-variance consensus errors
  Ξx/Ξy;
* ``ef_x_norm`` / ``ef_y_norm`` — error-feedback residual norms (present
  only under ``gossip_compress``; a growing residual means the quantizer is
  systematically starved).

Byzantine configuration (attacker count/model) is static per run and is
stamped into the run's ``meta`` event by the caller (``launch/train``), not
sampled here.
"""
from __future__ import annotations

from typing import Optional


def health_gauges(state) -> dict:
    """Algorithm-health gauges from a ``KGTState`` (host floats)."""
    import jax.numpy as jnp

    from repro.core import kgt_minimax as kgt
    from repro.core import mixing as mixing_lib

    out = {
        "corr_x_drift": float(kgt.correction_mean_norm(state.cx)),
        "corr_y_drift": float(kgt.correction_mean_norm(state.cy)),
        "consensus_x": float(mixing_lib.consensus_error(state.x)),
        "consensus_y": float(mixing_lib.consensus_error(state.y)),
    }
    for name in ("ef_x", "ef_y"):
        buf = getattr(state, name, None)
        if buf is not None:
            out[f"{name}_norm"] = float(
                jnp.sqrt(jnp.sum(jnp.square(buf.astype(jnp.float32)))))
    return out


class Profiler:
    """An N-round ``jax.profiler`` capture window.

    >>> prof = Profiler("/tmp/trace", num_rounds=8)
    >>> prof.start()                       # before engine.run
    >>> hooks.append(prof.hook)            # closes after 8 rounds
    >>> ...
    >>> prof.stop()                        # idempotent backstop

    ``num_rounds=0`` captures the whole run.  Failures to start/stop (no
    profiler backend in exotic builds) are swallowed after a one-line
    warning — profiling must never take a training run down.
    """

    def __init__(self, directory: str, num_rounds: int = 0) -> None:
        self.directory = directory
        self.num_rounds = int(num_rounds)
        self.active = False
        self._stop_round: Optional[int] = None

    def start(self) -> None:
        if self.active:
            return
        try:
            import jax.profiler

            jax.profiler.start_trace(self.directory)
            self.active = True
        except Exception as e:  # noqa: BLE001 — never take the run down
            print(f"[obs] profiler start failed: {e!r}", flush=True)

    def stop(self) -> None:
        if not self.active:
            return
        self.active = False
        try:
            import jax.profiler

            jax.profiler.stop_trace()
            print(f"[obs] profiler trace -> {self.directory}", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"[obs] profiler stop failed: {e!r}", flush=True)

    def hook(self, state, records, prev_round) -> None:
        """Engine chunk-boundary hook: close the window once ``num_rounds``
        rounds have run since capture started."""
        if not self.active or not self.num_rounds:
            return
        if self._stop_round is None:
            # first boundary after start(): the window began at prev_round
            self._stop_round = int(prev_round) + self.num_rounds
        if int(state.round) >= self._stop_round:
            self.stop()

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.stop()
        return False
