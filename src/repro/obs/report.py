"""Fold a telemetry JSONL into a run summary.

  PYTHONPATH=src python -m repro.obs.report /tmp/run.jsonl

Renders the time breakdown (per-span totals), the communication ledger
(bytes/round, GB total, collectives), throughput (rounds/s from the metric
stamps), and the convergence tail (the last logged metrics row).  Exits
nonzero on a missing, empty, or malformed artifact — ``scripts/smoke.sh``
uses that as the CI check that telemetry-producing runs stay well-formed.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

# metric-record bookkeeping stamps that are not convergence metrics
_STAMPS = ("v", "type", "t", "round", "wall_s", "compile_s", "run_s")


class ReportError(Exception):
    """A telemetry artifact that cannot be summarized."""


def load(path: str) -> List[dict]:
    """Parse a JSONL telemetry file; raise :class:`ReportError` on a
    missing/empty file or any malformed line (line number in the message)."""
    try:
        with open(path) as f:
            lines = f.readlines()
    except OSError as e:
        raise ReportError(f"cannot read {path}: {e}") from e
    events = []
    for i, line in enumerate(lines, 1):
        if not line.strip():
            continue
        try:
            ev = json.loads(line)
        except ValueError as e:
            raise ReportError(f"{path}:{i}: malformed JSONL line: {e}") from e
        if not isinstance(ev, dict) or "type" not in ev:
            raise ReportError(f"{path}:{i}: event is not a typed object")
        events.append(ev)
    if not events:
        raise ReportError(f"{path}: no telemetry events")
    return events


def summarize(events: List[dict]) -> dict:
    """Fold events into the summary dict :func:`render` prints.

    Every event type contributes: spans into the time breakdown, ledger
    events into the communication block, metrics into throughput + the
    convergence tail, counters/gauges into their last-value tables, meta
    into the run header.
    """
    spans: Dict[str, dict] = {}
    counters: Dict[str, dict] = {}
    gauges: Dict[str, float] = {}
    metrics: List[dict] = []
    ledger: Optional[dict] = None
    meta: dict = {}
    for ev in events:
        etype = ev.get("type")
        if etype == "span":
            s = spans.setdefault(ev.get("name", "?"),
                                 {"count": 0, "total_s": 0.0})
            s["count"] += 1
            s["total_s"] += float(ev.get("dur_s", 0.0))
        elif etype == "counter":
            c = counters.setdefault(ev.get("name", "?"),
                                    {"count": 0, "sum": 0.0})
            c["count"] += 1
            c["sum"] += float(ev.get("value", 0.0))
        elif etype == "gauge":
            gauges[ev.get("name", "?")] = float(ev.get("value", 0.0))
        elif etype == "metrics":
            metrics.append(ev)
        elif etype == "ledger":
            ledger = ev  # running totals: the last event wins
        elif etype == "meta":
            meta.update({k: v for k, v in ev.items()
                         if k not in ("v", "type", "t")})
    out: dict = {"num_events": len(events), "spans": spans,
                 "counters": counters, "gauges": gauges, "meta": meta}
    cache = {name[len("compile_cache."):]: c["sum"]
             for name, c in counters.items()
             if name.startswith("compile_cache.")}
    if cache:
        # the CompileCache emits integral counters; keep them integral
        out["compile_cache"] = {k: int(v) if float(v).is_integer() else v
                                for k, v in cache.items()}
    if metrics:
        last = metrics[-1]
        rounds = int(last.get("round", len(metrics) - 1)) + 1
        out["rounds"] = rounds
        out["num_metric_rows"] = len(metrics)
        run_s = last.get("run_s", last.get("wall_s"))
        if run_s:
            out["run_s"] = float(run_s)
            out["rounds_per_s"] = round(rounds / float(run_s), 3)
        if "compile_s" in last:
            out["compile_s"] = float(last["compile_s"])
        out["tail"] = {k: v for k, v in last.items() if k not in _STAMPS}
    if ledger is not None:
        bytes_total = int(ledger.get("bytes_total", 0))
        out["ledger"] = {
            "mixing_impl": ledger.get("mixing_impl"),
            "bytes_per_round": int(ledger.get("bytes_per_round", 0)),
            "collectives_per_round": int(
                ledger.get("collectives_per_round", 0)),
            "rounds": int(ledger.get("rounds_total", 0)),
            "bytes_total": bytes_total,
            "gb_total": round(bytes_total / 1e9, 6),
        }
    return out


def _fmt_bytes(b: int) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if b >= div:
            return f"{b / div:.3f} {unit}"
    return f"{b} B"


def render(summary: dict) -> str:
    """The human-readable summary table."""
    lines = []
    meta = summary.get("meta", {})
    if meta:
        head = " ".join(f"{k}={v}" for k, v in sorted(meta.items())
                        if not isinstance(v, (dict, list)))
        lines.append(f"run: {head}")
    lines.append(f"events: {summary['num_events']}")
    if summary.get("spans"):
        lines.append("time breakdown:")
        width = max(len(n) for n in summary["spans"])
        for name, s in sorted(summary["spans"].items(),
                              key=lambda kv: -kv[1]["total_s"]):
            lines.append(f"  {name:<{width}}  {s['total_s']:9.3f}s"
                         f"  x{s['count']}")
    if "rounds" in summary:
        thr = (f"  ({summary['rounds_per_s']} rounds/s over "
               f"{summary['run_s']:.3f}s run)"
               if "rounds_per_s" in summary else "")
        lines.append(f"rounds: {summary['rounds']} "
                     f"({summary['num_metric_rows']} logged){thr}")
    led = summary.get("ledger")
    if led:
        lines.append(
            f"communication [{led['mixing_impl']}]: "
            f"{_fmt_bytes(led['bytes_per_round'])}/round, "
            f"{led['collectives_per_round']} collectives/round, "
            f"{_fmt_bytes(led['bytes_total'])} total over "
            f"{led['rounds']} rounds")
    cc = summary.get("compile_cache")
    if cc:
        parts = [f"{k}={cc[k]}" for k in
                 ("hits", "memo_hits", "misses", "puts", "errors")
                 if k in cc]
        for k in ("bytes_read", "bytes_written"):
            if k in cc:
                parts.append(f"{k}={_fmt_bytes(int(cc[k]))}")
        lines.append("compile cache: " + ", ".join(parts))
    if summary.get("gauges"):
        lines.append("health (last sample):")
        for name, v in sorted(summary["gauges"].items()):
            lines.append(f"  {name} = {v:.6g}")
    if summary.get("tail"):
        tail = "  ".join(
            f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in sorted(summary["tail"].items())
            if not isinstance(v, (list, dict)))
        lines.append(f"convergence tail: {tail}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.report",
        description="Summarize a telemetry JSONL artifact")
    ap.add_argument("path", help="telemetry JSONL file (--telemetry-out)")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as JSON instead of a table")
    args = ap.parse_args(argv)
    try:
        summary = summarize(load(args.path))
    except ReportError as e:
        print(f"repro.obs.report: {e}", file=sys.stderr)
        return 1
    if args.json:
        print(json.dumps(summary, indent=1, default=str))
    else:
        print(render(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
