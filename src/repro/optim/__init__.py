from repro.optim.optimizers import Optimizer, adam, get_optimizer, momentum, sgd  # noqa: F401
from repro.optim.schedules import constant, cosine, get_schedule, wsd  # noqa: F401
