"""Minimal optimizer library (no optax in this environment).

Optimizers are (init, update) pairs over pytrees — ``update`` returns
(new_params, new_state).  Algorithm 1's faithful local update is plain SGD;
``momentum``/``adam`` are available as beyond-paper inner optimizers and for
the standalone (non-federated) training driver.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Any], tuple]  # (grads, state, params, lr)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params, lr):
        m = jax.tree.map(lambda s, g: beta * s + g.astype(jnp.float32), state, grads)
        new = jax.tree.map(
            lambda p, mm: (p.astype(jnp.float32) - lr * mm).astype(p.dtype), params, m)
        return new, m

    return Optimizer(init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda s, g: b1 * s + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda s, g: b2 * s + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        mh = 1.0 - b1 ** t.astype(jnp.float32)
        vh = 1.0 - b2 ** t.astype(jnp.float32)
        new = jax.tree.map(
            lambda p, mm, vv: (
                p.astype(jnp.float32) - lr * (mm / mh) / (jnp.sqrt(vv / vh) + eps)
            ).astype(p.dtype),
            params, m, v)
        return new, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


OPTIMIZERS = {"sgd": sgd, "momentum": momentum, "adam": adam}


def get_optimizer(name: str, **kw) -> Optimizer:
    return OPTIMIZERS[name](**kw)
