"""Learning-rate schedules (round-indexed): constant, cosine, and WSD
(warmup–stable–decay, the MiniCPM schedule, arXiv:2404.06395)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(total_rounds: int, warmup: int = 0):
    def fn(t):
        t = jnp.asarray(t, jnp.float32)
        return jnp.minimum(1.0, (t + 1) / jnp.maximum(warmup, 1)) if warmup else jnp.ones(())
    return fn


def cosine(total_rounds: int, warmup: int = 0, floor: float = 0.1):
    def fn(t):
        t = jnp.asarray(t, jnp.float32)
        wu = jnp.minimum(1.0, (t + 1) / jnp.maximum(warmup, 1))
        prog = jnp.clip((t - warmup) / jnp.maximum(total_rounds - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return wu * cos
    return fn


def wsd(total_rounds: int, warmup: int = 0, decay_start_frac: float = 0.8,
        floor: float = 0.1):
    """Warmup -> stable (lr=1) -> exponential-ish decay in the last
    (1-decay_start_frac) fraction of training."""
    def fn(t):
        t = jnp.asarray(t, jnp.float32)
        wu = jnp.minimum(1.0, (t + 1) / jnp.maximum(warmup, 1))
        start = decay_start_frac * total_rounds
        prog = jnp.clip((t - start) / jnp.maximum(total_rounds - start, 1), 0.0, 1.0)
        decay = floor ** prog  # 1 -> floor geometrically
        return wu * jnp.where(t < start, 1.0, decay)
    return fn


SCHEDULES = {"constant": constant, "cosine": cosine, "wsd": wsd}


def get_schedule(name: str, total_rounds: int, warmup: int = 0, **kw):
    return SCHEDULES[name](total_rounds, warmup, **kw)
