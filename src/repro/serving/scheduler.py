"""Continuous-batching serving loop.

A fixed pool of decode slots is stepped in lockstep (one jit'd decode step
per tick, the shape the decode dry-runs lower); a scheduler admits queued
requests into free slots between ticks, prefills them token-by-token into
the slot's cache region, and retires sequences on EOS/length.  This is the
vLLM-style iteration-level scheduling pattern, shaped for jit: static slot
count, static cache length, per-slot position/active masks as device arrays.

The batch dimension is the slot pool, so on the production mesh it shards
over the data axes exactly like the decode dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (P,) or (P, ncb)
    max_new_tokens: int
    temperature: float = 1.0
    eos_token: Optional[int] = None
    # filled by the engine:
    output: Optional[np.ndarray] = None


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    pos: int = 0                  # next absolute position to write
    prompt_cursor: int = 0        # tokens of the prompt already consumed
    generated: List = dataclasses.field(default_factory=list)


class ServingEngine:
    """Lockstep continuous-batching engine over ``num_slots`` sequences."""

    def __init__(self, cfg: ModelConfig, params, *, num_slots: int = 4,
                 max_len: int = 512, rng: int = 0):
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.caches = model_lib.init_cache(cfg, num_slots, max_len)
        self.slots = [_Slot() for _ in range(num_slots)]
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self.key = jax.random.PRNGKey(rng)
        self._tick = 0

        ncb = cfg.num_codebooks

        def step(params, caches, tokens, pos_vec, key, temps):
            # Each slot decodes at its OWN position: vmap over the cache batch
            # axis (axis 1 of every cache leaf) with a per-slot pos scalar.
            def one(p, c, t, pos):
                c1 = jax.tree.map(lambda x: x[:, None], c)  # reinsert batch=1
                logits, nc = model_lib.decode_step(p, c1, t[None], pos, self.cfg)
                return logits[0], jax.tree.map(lambda x: x[:, 0], nc)

            logits, new_caches = jax.vmap(
                one, in_axes=(None, 1, 0, 0), out_axes=(0, 1))(
                params, caches, tokens, pos_vec)
            flat = logits[:, -1].astype(jnp.float32)
            t_b = temps.reshape((-1,) + (1,) * (flat.ndim - 1))
            sampled = jax.random.categorical(key, flat / t_b, axis=-1)
            return sampled, new_caches

        self._step = jax.jit(step, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.request is None and self.queue:
                req = self.queue.pop(0)
                slot.request = req
                slot.pos = 0
                slot.prompt_cursor = 0
                slot.generated = []

    def _next_tokens(self):
        """Next input token per slot: prompt token (prefill phase) or the
        last sampled token (decode phase); idle slots feed token 0."""
        toks = []
        for slot in self.slots:
            if slot.request is None:
                toks.append(np.zeros(self._tok_shape(), np.int32))
            elif slot.prompt_cursor < len(slot.request.prompt):
                toks.append(np.asarray(
                    slot.request.prompt[slot.prompt_cursor], np.int32))
            else:
                toks.append(np.asarray(slot.generated[-1], np.int32))
        return jnp.asarray(np.stack(toks))[:, None] if not self.cfg.num_codebooks \
            else jnp.asarray(np.stack(toks))[:, None, :]

    def _tok_shape(self):
        return (self.cfg.num_codebooks,) if self.cfg.num_codebooks else ()

    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One lockstep decode step across all slots; returns #active."""
        self._admit()
        active = [s for s in self.slots if s.request is not None]
        if not active:
            return 0
        tokens = self._next_tokens()
        pos_vec = jnp.asarray([s.pos for s in self.slots], jnp.int32)
        temps = jnp.asarray(
            [s.request.temperature if s.request else 1.0 for s in self.slots],
            jnp.float32)
        self.key, ks = jax.random.split(self.key)
        sampled, self.caches = self._step(
            self.params, self.caches, tokens, pos_vec, ks, temps)
        sampled = np.asarray(sampled)

        for i, slot in enumerate(self.slots):
            req = slot.request
            if req is None:
                continue
            in_prefill = slot.prompt_cursor < len(req.prompt)
            slot.pos += 1
            if in_prefill:
                slot.prompt_cursor += 1
                if slot.prompt_cursor == len(req.prompt):
                    slot.generated.append(sampled[i])  # first real sample
            else:
                slot.generated.append(sampled[i])
            done_len = len(slot.generated) >= req.max_new_tokens
            done_eos = (req.eos_token is not None and slot.generated
                        and np.all(slot.generated[-1] == req.eos_token))
            done_cap = slot.pos >= self.max_len - 1
            if (not in_prefill or slot.prompt_cursor == len(req.prompt)) and (
                    done_len or done_eos or done_cap):
                req.output = np.stack(slot.generated)
                self.done[req.uid] = req
                slot.request = None
        self._tick += 1
        return len(active)

    def run(self, max_ticks: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_ticks):
            if not self.tick() and not self.queue:
                break
        return self.done
