"""Experiment-sweep subsystem: whole hyperparameter grids as single
compiled programs (see ``docs/architecture.md``, "The sweep subsystem").

``grid``    — GridSpec with static vs batchable axes, static-cell partition.
``batched`` — the vmapped trajectory chunk programs + early-stop freeze.
``run``     — cell/point drivers, ``run_sweep``, the ``repro.sweep.run`` CLI.
``defs``    — the paper-figure sweep definitions (V2–V5 + convergence).
``store``   — ``results/sweeps/<name>.json`` persistence with provenance.
"""
from repro.sweep.batched import (  # noqa: F401
    Trajectories,
    batch_sharding,
    make_batched_chunk_builder,
    make_quadratic_traj_sampler,
    make_trajectory_chunk_builder,
    tree_index,
    tree_stack,
    trajectory_chunk_program,
)
from repro.sweep.grid import (  # noqa: F401
    Axis,
    Cell,
    GridSpec,
    batch_axis,
    config_hash,
    point_key,
    static_axis,
)
# NOTE: repro.sweep.run (drivers + CLI) and repro.sweep.defs (the sweep
# definitions) are deliberately not imported here: `python -m
# repro.sweep.run` would re-execute an already-imported module (runpy
# RuntimeWarning), and both are cheap to import explicitly:
#     from repro.sweep import run as sweep_run
