"""Vmapped trajectory execution: a whole static cell as one scan program.

The execution unit is a :class:`Trajectories` pytree — algorithm state plus
everything that varies *within* a static cell carried as array leaves: the
per-client quadratic coefficients (``batches``), the traced stepsize bundle
(``etas``, see :func:`repro.core.point_etas`), the sampler ``seed``, and the
early-stop ``active`` mask.

``trajectory_chunk_program`` builds the **unbatched** program for one
trajectory — ``repro.engine.chunk_program`` (scan over rounds, device-side
sampling, optional metrics buffer) wrapped so the stepsizes/seed come from
the trajectory leaves and a finished trajectory is frozen by its ``active``
flag.  ``make_batched_chunk_builder`` jits ``vmap`` of exactly that program
over a stacked ``(B, …)`` trajectory axis.

This structural sharing is the bit-identity story: the sequential reference
path (``benchmarks.common.run_to_epsilon`` → ``repro.sweep.run.run_point``)
jits the *same* unbatched program, so the batched cell is literally its
vmap.  What does **not** survive bit-exactly is baking per-trajectory
scalars in as compile-time constants — XLA fuses constant-operand graphs
differently (an ulp per round) — which is why sigma and the etas are traced
operands on *both* paths, not closure constants.

The early-stop mask keeps the batch scanning after individual trajectories
converge: a frozen trajectory still flows through the scan (vmap has no
per-slice control flow) but a ``where(active, new, old)`` on every state
leaf — ``round`` included — pins it to the exact chunk boundary at which
the sequential ``stop_fn`` would have exited.

The batch axis is embarrassingly parallel, so when a ``jax.sharding.Mesh``
is supplied the stacked leaves are GSPMD-sharded over one of its axes
(default: the ``clients`` axis of the ``repro.dist`` decentralized mesh —
for sweep workloads batch-parallel beats client-parallel) and hundreds of
trajectories still cost one dispatch per chunk.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro import engine as engine_lib
from repro.dist.sharding import CLIENTS

# (round_idx, traj) -> (batches, keys): the trajectory-aware analogue of
# engine.sampler's Sampler protocol.
TrajSampler = Callable[[jnp.ndarray, "Trajectories"], Any]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Trajectories:
    """One trajectory (unbatched) or a stacked cell of B trajectories —
    every leaf gains a leading ``(B, …)`` dim under :func:`tree_stack`."""
    state: Any            # KGTState (n, …) leaves
    batches: Any          # fixed per-round batch pytree, (K, n, …) leaves
    etas: Dict[str, Any]  # traced stepsize bundle (repro.core.point_etas)
    seed: jnp.ndarray     # int32 sampler seed
    active: jnp.ndarray   # bool — False freezes the trajectory
    # churn bundle (None on fixed-topology cells): traced scalars feeding the
    # per-round W/mask draw — {"seed", "edge_prob", "drop_prob", "rate"}.
    # Like sigma/etas, these are leaves so one compiled cell serves every
    # edge-probability / participation-rate the grid batches over.
    topo: Any = None


def tree_stack(trees):
    """Stack a list of identical pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def tree_index(tree, i: int):
    """Slice trajectory ``i`` back out of a stacked pytree (host-side)."""
    return jax.tree.map(lambda x: x[i], tree)


def trajectory_chunk_program(
    round_step: Callable[[Any, Any, Any, Any], Any],
    traj_sampler: TrajSampler,
    metrics_fn=None,
    *,
    log_every: int = 1,
    length: int,
):
    """Unbatched ``chunk(traj, final_round) -> (traj, buffer)`` for one
    trajectory.  ``round_step`` is a ``make_round_step(traced_etas=True)``
    step; the engine's chunk program does the scanning/sampling/metrics
    work, this wrapper routes the trajectory leaves into it and applies the
    ``active`` freeze to the resulting state."""

    def chunk(traj: Trajectories, final_round):
        # extras (a sampled W / participation mask, when the trajectory
        # sampler draws them) slot in after the eta bundle — the order
        # make_round_step(traced_etas=True, traced_w=…, participation=…)
        # expects
        step = lambda st, b, k, *ex: round_step(st, b, k, traj.etas, *ex)
        sampler = lambda round_idx: traj_sampler(round_idx, traj)
        mfn = None
        if metrics_fn is not None:
            mfn = lambda st, b: metrics_fn(st, b, traj)
        program = engine_lib.chunk_program(
            step, sampler, mfn, log_every=log_every, length=length)
        new_state, buf = program(traj.state, final_round)
        frozen = jax.tree.map(
            lambda new, old: jnp.where(traj.active, new, old),
            new_state, traj.state)
        return dataclasses.replace(traj, state=frozen), buf

    return chunk


def make_trajectory_chunk_builder(
    round_step,
    traj_sampler: TrajSampler,
    metrics_fn=None,
    *,
    log_every: int = 1,
    donate: bool = True,
):
    """``build(length) -> jitted chunk(traj, final_round)`` for ONE
    trajectory — the sequential reference execution (`run_point`).  Same
    per-length caching contract as ``engine.make_chunk_builder``."""
    cache: Dict[int, Any] = {}

    def build(length: int):
        if length not in cache:
            fn = trajectory_chunk_program(
                round_step, traj_sampler, metrics_fn,
                log_every=log_every, length=length)
            cache[length] = jax.jit(fn, donate_argnums=(0,) if donate else ())
        return cache[length]

    return build


def batch_sharding(mesh, axis: str = CLIENTS):
    """NamedSharding placing the stacked trajectory axis (the leading dim of
    every ``Trajectories`` leaf) on ``axis`` of ``mesh``.  Used as a jit
    in/out-sharding *prefix*: one spec covers the whole pytree."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    return NamedSharding(mesh, P(axis))


def make_batched_chunk_builder(
    round_step,
    traj_sampler: TrajSampler,
    metrics_fn=None,
    *,
    log_every: int = 1,
    donate: bool = True,
    mesh=None,
    mesh_axis: str = CLIENTS,
):
    """``build(length) -> jitted chunk(trajs, final_round)`` over a stacked
    ``(B, …)`` cell — ``vmap`` of :func:`trajectory_chunk_program`, one
    dispatch per chunk for the whole batch.

    With ``mesh``, the batch axis of every input/output leaf is sharded over
    ``mesh_axis`` (B must divide the axis size ·k); the metrics buffer, when
    present, is left for GSPMD to place (it is read back per chunk anyway).
    """
    cache: Dict[int, Any] = {}

    def build(length: int):
        if length not in cache:
            fn = trajectory_chunk_program(
                round_step, traj_sampler, metrics_fn,
                log_every=log_every, length=length)
            batched = jax.vmap(fn, in_axes=(0, None))
            kwargs: dict = {"donate_argnums": (0,) if donate else ()}
            if mesh is not None:
                shard = batch_sharding(mesh, mesh_axis)
                from jax.sharding import NamedSharding
                from jax.sharding import PartitionSpec as P

                kwargs["in_shardings"] = (shard, NamedSharding(mesh, P()))
                kwargs["out_shardings"] = (shard, None)
            cache[length] = jax.jit(batched, **kwargs)
        return cache[length]

    return build


def make_quadratic_traj_sampler(*, local_steps: int, num_clients: int):
    """The quadratic benchmark sampler as a :data:`TrajSampler`: fixed
    per-round batches from the trajectory, oracle keys from the trajectory's
    *traced* seed on the historical ``PRNGKey(seed·7919 + t)`` schedule
    (``engine.make_fixed_batch_sampler``'s, with the seed an operand instead
    of a Python constant — integer key arithmetic is exact, so the drawn
    noise is unchanged)."""

    def sample(round_idx, traj: Trajectories):
        keys = jax.random.split(
            jax.random.PRNGKey(traj.seed * 7919 + round_idx),
            local_steps * num_clients,
        ).reshape(local_steps, num_clients, 2)
        return traj.batches, keys

    return sample


def make_churn_traj_sampler(*, local_steps: int, num_clients: int,
                            family: str, base_w=None,
                            participation: bool = False,
                            sparse_support=None,
                            byzantine: bool = False):
    """:func:`make_quadratic_traj_sampler` plus the churn draws: each round
    also samples the mixing matrix (``family`` ≠ "static"), the
    participation mask, and/or the Byzantine adversary from the trajectory's
    traced ``topo`` bundle.

    The family, the participation flag, and the byzantine flag are static
    cell properties; the bundle's scalars (topology seed, edge probability,
    drop probability, participation rate, attacker count/id/scale) are
    trajectory leaves, so e.g. an edge-probability or attack-type grid axis
    batches into one compiled cell.  All draws go through
    ``stochastic_topology.round_stream_key`` — pure in the round index —
    which is what keeps the vmapped cell bit-identical to the sequential
    reference and checkpoint restores exact.

    With ``sparse_support`` (a host-concrete
    ``repro.core.sparse_topology.SparseTopology``) the W draw goes through
    ``make_sparse_w_sampler`` on that support instead — the extras slot
    carries a ``SparseTopology`` pytree, never an (n, n) array, matching a
    ``mixing_impl="sparse_packed"`` round step.  ``base_w`` is ignored on
    that path (the support *is* the base topology).
    """
    from repro.core import adversary as adversary_lib
    from repro.core import sparse_topology as sparse
    from repro.core import stochastic_topology as stoch

    if family not in stoch.TOPOLOGY_FAMILIES:
        raise ValueError(
            f"unknown topology family {family!r}: {stoch.TOPOLOGY_FAMILIES}")
    # compose over the fixed-topology sampler: churn cells must draw the
    # same data/oracle-key stream as non-churn cells of the same seed
    base_sample = make_quadratic_traj_sampler(
        local_steps=local_steps, num_clients=num_clients)

    def sample(round_idx, traj: Trajectories):
        batches, keys = base_sample(round_idx, traj)
        topo = traj.topo
        tkey = jax.random.PRNGKey(topo["seed"])
        extras = []
        if family != "static":
            if sparse_support is not None:
                w_fn = sparse.make_sparse_w_sampler(
                    family, sparse_support, tkey,
                    edge_prob=topo["edge_prob"],
                    client_drop_prob=topo["drop_prob"])
            else:
                w_fn = stoch.make_w_sampler(
                    family, num_clients, tkey, base_w=base_w,
                    edge_prob=topo["edge_prob"],
                    client_drop_prob=topo["drop_prob"])
            extras.append(w_fn(round_idx))
        if participation:
            extras.append(stoch.bernoulli_mask(
                stoch.round_stream_key(tkey, round_idx, stoch.MASK_STREAM),
                num_clients, topo["rate"]))
        if byzantine:
            extras.append(adversary_lib.Adversary(
                ids=adversary_lib.attack_ids(
                    num_clients, topo["num_byzantine"], topo["attack_id"]),
                key=stoch.round_stream_key(
                    tkey, round_idx, adversary_lib.ATTACK_STREAM),
                scale=jnp.float32(topo["attack_scale"])))
        return batches, keys, tuple(extras)

    return sample
