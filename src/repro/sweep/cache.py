"""Persistent compilation cache for sweep cells and engine chunk programs.

bench_sweep's diagnosis (ROADMAP: "Kill compile time as the sweep
bottleneck"): the sequential sweep path spends ~48 s of a 53 s wall in XLA
compilation, and even the batched path compiles for seconds to run for
sub-seconds.  Every process recompiles every static cell from scratch, so
"run the paper grid on every PR" is priced in compiler time, not math.
This module removes that price in three layers:

1. **jax's built-in persistent compilation cache** — :func:`enable_xla_cache`
   roots ``jax_compilation_cache_dir`` under ``results/.xla_cache/xla`` (or
   ``$REPRO_XLA_CACHE``) with the size/time thresholds dropped to zero, so
   a repeated ``lower().compile()`` skips the XLA backend compile.  The
   process still pays tracing + lowering per program, which is why layer 2
   exists.

2. **An AOT executable cache** — :class:`CompileCache` serializes
   ``jax.jit(...).lower(...).compile()`` executables
   (``jax.experimental.serialize_executable``) to disk, keyed on a stable
   signature: the program *kind* + the static-cell statics tuple + the
   abstract avals (shape/dtype/pytree structure) of the example arguments +
   the jax version/backend fingerprint + a content hash of the git-tracked
   ``repro.core`` / ``repro.engine`` / ``repro.kernels`` / ``repro.sweep``
   sources (:func:`code_hash`).  A warm process deserializes in ~30 ms what
   cold-compiles in seconds, and **skips tracing and lowering entirely**.
   A code change rotates the key (stale entries are simply never hit); a
   corrupt or checksum-failing entry is reported loudly on stderr, deleted,
   and recompiled.  Entries embed their full key material and are verified
   on load, so a key-construction bug surfaces as a loud mismatch instead
   of a silent wrong-program execution.

   Keys deliberately contain **only** information that determines the traced
   program: anything baked into the jaxpr as a closure constant must be in
   the statics tuple (the sweep paths qualify because PR 4 made every
   per-point quantity a traced operand; callers with baked data — e.g. the
   train driver's data model — must fold the generating config into
   ``statics``, see ``launch/train.py``).

3. **Shape-bucket reuse** — cells differing only in paddable dimensions
   share one executable instead of recompiling per shape:

   * :func:`bucket_batch` pads the vmapped cell's trajectory axis up to the
     next power of two (≤ 8) / multiple of 8 — the same n→8 sublane
     discipline the Pallas kernels apply internally — with padding
     trajectories frozen by the existing ``active`` mask, so a 5-point and
     a 7-point cell both run the B=8 program (``pad_trajectories``; vmap is
     slice-bit-stable for the scan programs, so real rows are unchanged —
     tests/test_cache.py pins that).  Inside the kernels the n→8 / dz→128
     padding already happens pre-``pallas_call``, so kernel programs bucket
     for free once their callers do.
   * :func:`length_schedule` decomposes an arbitrary scan length into
     descending powers of two (10 → 8+2), so cells differing only in
     ``eval_every`` / ``max_rounds`` remainders draw from one small shared
     pool of chunk executables instead of compiling per distinct length.
     Splitting a scan at a chunk boundary is bit-exact: the carried state
     is identical and the per-round bodies key off ``state.round``.

Environment plumbing (both respected by the sweep CLI, ``launch/train`` and
``launch/dryrun``):

* ``REPRO_COMPILE_CACHE`` — ``off``/``0`` disables; a path roots the whole
  stack (``<path>/aot`` + ``<path>/xla``); ``1``/``on``/``auto`` uses the
  default root ``results/.xla_cache``.
* ``REPRO_XLA_CACHE``     — overrides just the layer-1 directory.

Cache traffic is observable: hit/miss/error/put counters and byte totals
flow through ``repro.obs`` as ``compile_cache.*`` counter events (folded
into a ``compile_cache`` block by ``repro.obs.report``), and
``sweep/store.py`` stamps the same stats into every stored sweep's
provenance.

Entries are pickles — treat a cache directory with the same trust as the
code that wrote it (it is a local build artifact, not an interchange
format).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import re
import subprocess
import sys
import tempfile
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

CACHE_VERSION = 1

ENV_CACHE = "REPRO_COMPILE_CACHE"
ENV_XLA_CACHE = "REPRO_XLA_CACHE"

_OFF_VALUES = ("", "0", "off", "none", "false", "disabled")
_ON_VALUES = ("1", "on", "auto", "true")

#: Packages whose sources key the executables (a change in any of them must
#: rotate every cached program — they define the traced computations).
CODE_HASH_PACKAGES = ("core", "engine", "kernels", "sweep")


def repo_root() -> str:
    from repro.sweep import store as store_lib

    return store_lib.repo_root()


def default_root() -> str:
    """``<repo>/results/.xla_cache`` — gitignored scratch, like the rest of
    ``results/`` outside the curated artifacts."""
    return os.path.join(repo_root(), "results", ".xla_cache")


# ---------------------------------------------------------------------------
# layer 1: jax's built-in persistent compilation cache


def enable_xla_cache(root: Optional[str] = None) -> Optional[str]:
    """Point ``jax_compilation_cache_dir`` at ``root`` (default
    ``results/.xla_cache/xla``; ``$REPRO_XLA_CACHE`` overrides, with the
    off-values disabling).  Thresholds are dropped so even the sweep's
    sub-second programs persist.  Returns the active directory, or None
    when disabled.  Idempotent — safe to call from every entry point."""
    env = os.environ.get(ENV_XLA_CACHE)
    if env is not None:
        if env.strip().lower() in _OFF_VALUES:
            return None
        if env.strip().lower() not in _ON_VALUES:
            root = env
    root = root or os.path.join(default_root(), "xla")
    os.makedirs(root, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", root)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    try:
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except AttributeError:  # pragma: no cover - older jax spelling
        pass
    return root


# ---------------------------------------------------------------------------
# key material


_CODE_HASH: Dict[str, str] = {}


def _git_tracked_sources() -> Optional[list]:
    rel = [f"src/repro/{p}" for p in CODE_HASH_PACKAGES]
    try:
        out = subprocess.run(
            ["git", "ls-files", "--", *rel], cwd=repo_root(),
            capture_output=True, text=True, timeout=10)
    except (OSError, subprocess.SubprocessError):
        return None
    files = [ln for ln in out.stdout.splitlines() if ln.strip()]
    return sorted(files) or None


def _walked_sources() -> list:
    files = []
    root = repo_root()
    for pkg in CODE_HASH_PACKAGES:
        base = os.path.join(root, "src", "repro", pkg)
        for dirpath, _, names in os.walk(base):
            for name in names:
                if name.endswith(".py"):
                    files.append(os.path.relpath(
                        os.path.join(dirpath, name), root))
    return sorted(files)


def code_hash() -> str:
    """Content hash of the ``repro.core``/``engine``/``kernels``/``sweep``
    sources — the part of the cache key that invalidates every executable
    when the programs they encode change.  Git-tracked file list when
    available (uncommitted edits still hash through the file *contents*),
    plain package walk otherwise.  Memoized per process."""
    if "hash" in _CODE_HASH:
        return _CODE_HASH["hash"]
    files = _git_tracked_sources() or _walked_sources()
    h = hashlib.sha256()
    root = repo_root()
    for rel in files:
        path = os.path.join(root, rel)
        try:
            with open(path, "rb") as f:
                blob = f.read()
        except OSError:
            blob = b"<unreadable>"
        h.update(rel.encode())
        h.update(b"\0")
        h.update(blob)
        h.update(b"\0")
    _CODE_HASH["hash"] = h.hexdigest()[:16]
    return _CODE_HASH["hash"]


def backend_fingerprint() -> Tuple[str, ...]:
    """What the serialized executable is only valid for: jax version,
    platform, device kind, and local device count (the executable embeds
    its device assignment)."""
    dev = jax.devices()[0]
    return (jax.__version__, dev.platform,
            str(getattr(dev, "device_kind", "")), str(jax.device_count()))


def _freeze(obj: Any) -> Any:
    """Canonical hashable/repr-stable form of a statics structure."""
    if isinstance(obj, dict):
        return tuple((str(k), _freeze(v)) for k, v in sorted(obj.items()))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def _aval_signature(args: tuple) -> Tuple[str, Tuple]:
    """(pytree structure, per-leaf (shape, dtype)) of the example call —
    the shape half of the key.  Non-array leaves key on their repr."""
    leaves, treedef = jax.tree.flatten(args)
    sig = []
    for leaf in leaves:
        shape = getattr(leaf, "shape", None)
        dtype = getattr(leaf, "dtype", None)
        if shape is not None and dtype is not None:
            sig.append((tuple(shape), str(dtype)))
        else:
            sig.append(("pyleaf", repr(leaf)))
    return str(treedef), tuple(sig)


def key_material(kind: str, statics: Any, args: tuple) -> tuple:
    """The full, human-inspectable tuple the key hashes (also embedded in
    every cache entry and verified on load)."""
    treedef, avals = _aval_signature(args)
    return (CACHE_VERSION, kind, _freeze(statics), treedef, avals,
            code_hash(), backend_fingerprint())


def program_key(kind: str, statics: Any, args: tuple) -> str:
    return hashlib.sha256(repr(key_material(kind, statics, args))
                          .encode()).hexdigest()


# ---------------------------------------------------------------------------
# layer 3: shape buckets


def bucket_batch(b: int) -> int:
    """Trajectory-batch bucket: next power of two up to 8, then multiples
    of 8 — mirroring the kernels' n→8 sublane padding, so cells whose point
    counts differ only within a bucket share one vmapped executable."""
    b = int(b)
    if b <= 1:
        return 1
    if b <= 8:
        return 1 << (b - 1).bit_length()
    return -(-b // 8) * 8


def length_schedule(length: int) -> Tuple[int, ...]:
    """Decompose a scan length into descending powers of two (10 → (8, 2)).
    Chunks compose bit-exactly, so any ``eval_every``/remainder length is
    served from O(log length) shared executables."""
    length = int(length)
    if length <= 0:
        return ()
    out = []
    p = 1 << (length.bit_length() - 1)
    while length:
        if p <= length:
            out.append(p)
            length -= p
        p >>= 1
    return tuple(out)


def pad_trajectories(trajs, pad: int):
    """Pad the stacked trajectory axis with ``pad`` copies of trajectory 0,
    frozen from round 0 by ``active=False`` — the batch-bucket filler.  The
    padding rows still flow through the scan (vmap has no per-slice control
    flow) but their state never changes, and callers slice results back to
    the real batch."""
    if pad <= 0:
        return trajs
    new = jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (pad, *x.shape[1:]))]), trajs)
    active = jnp.concatenate(
        [trajs.active, jnp.zeros((pad,), trajs.active.dtype)])
    return dataclasses.replace(new, active=active)


# ---------------------------------------------------------------------------
# layer 2: the AOT executable cache


def _loud(msg: str) -> None:
    print(f"[compile-cache] {msg}", file=sys.stderr, flush=True)


def _scan_custom_calls(compiled) -> Tuple[str, ...]:
    """The custom-call targets of a compiled executable's optimized HLO.

    XLA resolves these *by name at call time with no existence check*: a
    deserialized executable whose targets nobody registered in this process
    segfaults instead of raising.  jax registers them as a side effect of
    *lowering* the originating op (e.g. the LAPACK qr/svd family on first
    ``jnp.linalg`` trace) — exactly the step the AOT cache skips — so every
    entry records its targets and :func:`_ensure_runtime` re-registers them
    before the executable is loaded.  ``("?",)`` when the executable cannot
    be introspected (best-effort warmup applies).
    """
    try:
        mods = compiled._executable.xla_executable.hlo_modules()
        txt = "\n".join(m.to_string() for m in mods)
    except Exception:
        return ("?",)
    return tuple(sorted(set(
        re.findall(r'custom_call_target="([^"]+)"', txt))))


def _ensure_runtime(targets: Tuple[str, ...]) -> bool:
    """Register the runtime handlers for ``targets`` in this process, or
    report False (the caller recompiles instead of risking a segfault)."""
    for t in targets:
        if t.startswith("lapack_") or t.startswith("blas_") or t == "?":
            # importing jaxlib.lapack runs its register_custom_call_target
            # loop, and initialize() binds the scipy-provided kernel
            # pointers the handlers dispatch to — jax normally does both
            # lazily inside the linalg *lowering* rules this cache skips
            import jaxlib.lapack

            jaxlib.lapack._lapack.initialize()
        else:
            return False
    return True


class CompileCache:
    """Disk cache of serialized XLA executables + an in-process memo.

    ``get_or_compile(kind, statics, fn, args)`` returns a callable with the
    same signature as ``fn`` — a memoized executable, a deserialized disk
    entry, or a freshly AOT-compiled (and stored) one, in that order —
    plus an info dict (``source`` ∈ memo/disk/compile/fallback, and the
    seconds spent compiling/deserializing).  ``fn`` must be a ``jax.jit``
    product (anything exposing ``.lower(*args).compile()``); a plain
    callable passes through untouched as ``source="uncacheable"``.

    ``telemetry`` (a ``repro.obs.Telemetry``) receives ``compile_cache.*``
    counters per event; ``stats`` accumulates the same numbers in-process.
    """

    def __init__(self, root: Optional[str] = None, *, telemetry=None,
                 bucket_batch: bool = True, bucket_lengths: bool = True):
        self.root = root or os.path.join(default_root(), "aot")
        self.telemetry = telemetry
        self.bucket_batch = bucket_batch
        self.bucket_lengths = bucket_lengths
        self.memo: Dict[str, Any] = {}
        self.stats: Dict[str, float] = {
            "hits": 0, "misses": 0, "errors": 0, "puts": 0, "memo_hits": 0,
            "bytes_read": 0, "bytes_written": 0,
            "compile_s": 0.0, "deserialize_s": 0.0,
        }

    # -- bookkeeping --------------------------------------------------------

    def _count(self, name: str, value=1, **attrs) -> None:
        self.stats[name] = self.stats.get(name, 0) + value
        if self.telemetry is not None:
            self.telemetry.counter(f"compile_cache.{name}", value, **attrs)

    def describe(self) -> dict:
        """Provenance-grade snapshot (``sweep/store.py`` stamps this)."""
        out = {"root": self.root, "code_hash": code_hash(),
               "cache_version": CACHE_VERSION}
        out.update({k: (round(v, 3) if isinstance(v, float) else v)
                    for k, v in self.stats.items()})
        return out

    # -- disk entries -------------------------------------------------------

    def _path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.aotc")

    def load(self, key: str, material: tuple):
        """The executable stored under ``key``, or None (miss).  Corrupt,
        truncated, checksum-failing, or key-mismatched entries are deleted
        and reported loudly — the caller recompiles."""
        path = self._path(key)
        try:
            with open(path, "rb") as f:
                blob = f.read()
        except FileNotFoundError:
            return None
        except OSError as e:
            self._count("errors", key=key)
            _loud(f"unreadable entry {path} ({e}); recompiling")
            return None
        t0 = time.perf_counter()
        try:
            entry = pickle.loads(blob)
            if entry["version"] != CACHE_VERSION:
                raise ValueError(f"cache version {entry['version']} != "
                                 f"{CACHE_VERSION}")
            if entry["material"] != repr(material):
                raise ValueError("key material mismatch (hash collision or "
                                 "key-construction bug)")
            payload = entry["payload"]
            if hashlib.sha256(payload).hexdigest() != entry["checksum"]:
                raise ValueError("payload checksum mismatch")
            targets = tuple(entry.get("custom_calls", ("?",)))
            if not _ensure_runtime(targets):
                raise ValueError(
                    f"cannot register custom-call targets {targets} in "
                    "this process (calling the executable would crash)")
            from jax.experimental import serialize_executable as se

            loaded = se.deserialize_and_load(
                payload, entry["in_tree"], entry["out_tree"])
        except Exception as e:  # corrupt/stale in any way -> recompile loudly
            self._count("errors", key=key)
            _loud(f"corrupt entry {path} ({type(e).__name__}: {e}); "
                  "deleting and recompiling")
            try:
                os.remove(path)
            except OSError:
                pass
            return None
        dur = time.perf_counter() - t0
        self.stats["deserialize_s"] += dur
        self._count("hits", kind=material[1])
        self._count("bytes_read", len(blob), kind=material[1])
        return loaded, dur

    def store(self, key: str, material: tuple, compiled) -> None:
        """Serialize ``compiled`` under ``key`` (atomic tmp+rename write —
        concurrent sweep processes at worst both write the same bytes).
        Failures are loud but non-fatal: the run proceeds uncached."""
        try:
            from jax.experimental import serialize_executable as se

            payload, in_tree, out_tree = se.serialize(compiled)
            blob = pickle.dumps({
                "version": CACHE_VERSION,
                "material": repr(material),
                "checksum": hashlib.sha256(payload).hexdigest(),
                "custom_calls": _scan_custom_calls(compiled),
                "payload": payload,
                "in_tree": in_tree,
                "out_tree": out_tree,
            })
            os.makedirs(self.root, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._path(key))
        except Exception as e:
            self._count("errors", key=key)
            _loud(f"failed to store entry {key[:12]}… "
                  f"({type(e).__name__}: {e}); run proceeds uncached")
            return
        self._count("puts", kind=material[1])
        self._count("bytes_written", len(blob), kind=material[1])

    # -- the main entry point ----------------------------------------------

    def get_or_compile(self, kind: str, statics: Any, fn, args: tuple):
        """See class docstring.  Returns ``(callable, info)``."""
        lower = getattr(fn, "lower", None)
        if lower is None:
            return fn, {"source": "uncacheable",
                        "compile_s": 0.0, "deserialize_s": 0.0}
        material = key_material(kind, statics, args)
        key = program_key(kind, statics, args)
        if key in self.memo:
            self._count("memo_hits", kind=kind)
            return self.memo[key], {"source": "memo",
                                    "compile_s": 0.0, "deserialize_s": 0.0}
        hit = self.load(key, material)
        if hit is not None:
            loaded, dur = hit
            self.memo[key] = loaded
            return loaded, {"source": "disk",
                            "compile_s": 0.0, "deserialize_s": dur}
        self._count("misses", kind=kind)
        t0 = time.perf_counter()
        try:
            compiled = lower(*args).compile()
        except Exception as e:
            _loud(f"AOT lowering failed for {kind} "
                  f"({type(e).__name__}: {e}); falling back to on-demand jit")
            self._count("errors", kind=kind)
            return fn, {"source": "fallback",
                        "compile_s": 0.0, "deserialize_s": 0.0}
        dur = time.perf_counter() - t0
        self.stats["compile_s"] += dur
        self.store(key, material, compiled)
        self.memo[key] = compiled
        return compiled, {"source": "compile",
                          "compile_s": dur, "deserialize_s": 0.0}


# ---------------------------------------------------------------------------
# defaults / env resolution


#: Sentinel for "no explicit cache argument": resolve from the environment.
UNSET = object()

_DEFAULT: Dict[str, Any] = {}


def from_env(telemetry=None) -> Optional[CompileCache]:
    """The process-wide default cache per ``$REPRO_COMPILE_CACHE`` (None
    when unset/off).  Memoized so repeated ``run_point`` calls share one
    executable memo; setting the env var also arms layer 1 under the same
    root."""
    value = os.environ.get(ENV_CACHE)
    if value is None or value.strip().lower() in _OFF_VALUES:
        return None
    if value in _DEFAULT:
        cache = _DEFAULT[value]
    else:
        root = (default_root() if value.strip().lower() in _ON_VALUES
                else value)
        enable_xla_cache(os.path.join(root, "xla"))
        cache = CompileCache(os.path.join(root, "aot"))
        _DEFAULT[value] = cache
    if telemetry is not None:
        cache.telemetry = telemetry
    return cache


def resolve(cache, telemetry=None) -> Optional[CompileCache]:
    """Normalize a ``cache=`` keyword: :data:`UNSET` → env default,
    None → disabled, a :class:`CompileCache` → itself."""
    if cache is UNSET:
        return from_env(telemetry)
    if cache is not None and telemetry is not None and cache.telemetry is None:
        cache.telemetry = telemetry
    return cache
