"""The paper-figure sweeps, encoded as :class:`repro.sweep.grid.GridSpec`\\s.

Each definition reproduces one of the theory-validation experiments (V2–V5
in DESIGN.md / Theorem 1's scaling terms) as a *grid* rather than a row of
one-off runs: the varied quantity plus a seed-replicate axis, so every
figure point carries error bars.  Axis kinds follow the compilation
boundary — K / topology / n / algorithm change the traced program (static),
seed / heterogeneity / sigma / stepsizes are array leaves (batchable).

``benchmarks/bench_{local_steps,heterogeneity,topology,speedup,convergence}``
are thin wrappers over these definitions; ``python -m repro.sweep.run
<name>`` runs them standalone and persists ``results/sweeps/<name>.json``.
"""
from __future__ import annotations

from repro.core import mixing_matrix, spectral_gap
from repro.sweep.grid import GridSpec, batch_axis, static_axis

SEEDS = (0, 1, 2, 3)

SWEEPS = {}


def register(spec: GridSpec) -> GridSpec:
    SWEEPS[spec.name] = spec
    return spec


def _eta_over_k(p):
    """V2's theory-prescribed stepsizes: η_c ∝ 1/K for stability."""
    return {"eta_cx": 0.02 / p["K"], "eta_cy": 0.2 / p["K"]}


def _eta_s_by_algo(p):
    """η_s = 0.5 for the tracking variants, 1.0 (plain averaging) else."""
    return {"eta_s": 0.5 if p["algorithm"] in ("kgt_minimax", "gt_gda") else 1.0}


def _eta_s_by_gap(p):
    """V4's connectivity-matched communication stepsize."""
    gap = spectral_gap(mixing_matrix(p["topology"], p["n"]))
    return {"eta_s": min(0.9, 0.6 + 0.4 * gap)}


# V2: T vs K — local updates amortize gradient noise (σ²/(nK ε⁴) term).
register(GridSpec(
    name="local_steps",
    base=dict(n=8, sigma=2.0, heterogeneity=1.0, eps=0.6, eta_s=0.5,
              max_rounds=400, eval_every=20),
    axes=(static_axis("K", 1, 2, 4, 8, 16),
          batch_axis("seed", *SEEDS)),
    derive=_eta_over_k,
))

# V3: heterogeneity robustness — tracking flat in DH, local SGDA degrades.
register(GridSpec(
    name="heterogeneity",
    base=dict(n=8, K=8, sigma=0.0, eps=0.2, eta_cx=0.01, eta_cy=0.1,
              max_rounds=1200),
    axes=(static_axis("algorithm", "kgt_minimax", "local_sgda"),
          batch_axis("heterogeneity", 0.0, 1.0, 2.0, 4.0),
          batch_axis("seed", *SEEDS)),
    derive=_eta_s_by_algo,
))

# V4: topology dependence — rounds-to-ε vs spectral quantity p.
register(GridSpec(
    name="topology",
    base=dict(n=16, K=4, sigma=0.0, heterogeneity=2.0, eps=0.2,
              eta_cx=0.01, eta_cy=0.1, max_rounds=2500),
    axes=(static_axis("topology", "full", "exp", "torus", "ring"),
          batch_axis("seed", *SEEDS)),
    derive=_eta_s_by_gap,
))

# V5: linear speedup in n on the stochastic term.
register(GridSpec(
    name="speedup",
    base=dict(K=4, sigma=1.0, heterogeneity=0.5, topology="full", eps=0.45,
              eta_cx=0.01, eta_cy=0.1, eta_s=1.0, max_rounds=4000,
              eval_every=20),
    axes=(static_axis("n", 2, 4, 8, 16),
          batch_axis("seed", *SEEDS)),
))

# Table-1 proxy, seed-replicated: mean±std across 8 seeds per algorithm.
register(GridSpec(
    name="convergence",
    base=dict(n=8, K=8, sigma=0.1, heterogeneity=2.0, eps=0.3,
              eta_cx=0.01, eta_cy=0.1, max_rounds=1500),
    axes=(static_axis("algorithm", "kgt_minimax", "gt_gda", "dsgda",
                      "local_sgda"),
          batch_axis("seed", *range(8))),
    derive=_eta_s_by_algo,
))

def _pin_unread_edge_prob(p):
    """edge_prob only parameterizes the erdos_renyi draw; pinning it
    elsewhere + dedup stops the other families running bit-identical
    trajectories twice and counting them as replicates."""
    return {} if p["topology_family"] == "erdos_renyi" else {"edge_prob": 0.5}


# V6 (beyond-paper): robustness to churn — time-varying random topologies
# (repro.core.stochastic_topology families) × partial client participation.
# The family is a static cell split; edge probability and participation
# rate are traced leaves, with the participation axis spanning 1.0 split on
# "are mask ops in the graph" exactly like sigma on noise ops.
register(GridSpec(
    name="churn",
    base=dict(n=8, K=4, sigma=0.0, heterogeneity=2.0, topology="full",
              eps=0.25, eta_cx=0.01, eta_cy=0.1, eta_s=0.5,
              max_rounds=600, eval_every=25),
    axes=(static_axis("topology_family",
                      "static", "erdos_renyi", "pairwise", "dropout"),
          batch_axis("edge_prob", 0.3, 0.7),
          batch_axis("participation", 1.0, 0.7,
                     cell_key=lambda r: r < 1),
          batch_axis("seed", 0, 1)),
    derive=_pin_unread_edge_prob,
    dedup=True,
))

def _pin_honest(p):
    """The attack type/scale only exist when there are attackers; pinning
    them at f=0 + dedup collapses the attack axis to one honest baseline
    per (mixing_impl, seed) instead of three identical replicates."""
    if p["num_byzantine"] > 0:
        return {}
    return {"attack": "honest", "attack_scale": 1.0}


# V7 (beyond-paper): Byzantine robustness — f = ⌈n/8⌉ attackers corrupting
# their outgoing round deltas (repro.core.adversary) against plain mean
# gossip vs the robust aggregation lowerings (coord_median / trimmed_mean).
# The aggregation rule is a static cell split (a different mixing program);
# attacker count / attack id / attack scale are traced bundle leaves, with
# the num_byzantine axis spanning 0 split on "is the adversary extras slot
# in the graph" exactly like participation on mask ops.
#
# heterogeneity=0 is the classic homogeneous Byzantine setting: the
# coordinate-wise robust rules pay an irreducible bias ∝ client
# heterogeneity (trimming heterogeneous honest deltas biases the fixed
# point — per-client curvature still differs at 0, only the linear terms
# coincide), so the attacked robust floors clear eps only when that bias
# is small.  The headline contrast survives at any heterogeneity (plain
# gossip diverges, robust plateaus); what moves is the plateau.
register(GridSpec(
    name="adversary",
    base=dict(n=8, K=4, sigma=0.0, heterogeneity=0.0, topology="full",
              eps=0.25, eta_cx=0.01, eta_cy=0.1, eta_s=0.5,
              max_rounds=600, eval_every=25,
              attack_scale=3.0, robust_trim=1),
    axes=(static_axis("mixing_impl", "dense", "coord_median",
                      "trimmed_mean"),
          batch_axis("attack", "sign_flip", "large_norm", "random_noise"),
          batch_axis("num_byzantine", 0, 1,
                     cell_key=lambda f: f > 0),
          batch_axis("seed", 0, 1)),
    derive=_pin_honest,
    dedup=True,
))

# CI smoke: 2 seeds × 2 heterogeneity levels, one tiny cell end-to-end
# (batched path + store write) — scripts/smoke.sh runs this.
register(GridSpec(
    name="smoke",
    base=dict(n=4, K=2, sigma=0.5, eps=0.5, eta_cx=0.02, eta_cy=0.2,
              eta_s=0.5, max_rounds=40, eval_every=10),
    axes=(batch_axis("heterogeneity", 0.5, 1.5),
          batch_axis("seed", 0, 1)),
))
