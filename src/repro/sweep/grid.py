"""Grid specification and static-cell partitioning for ``repro.sweep``.

A sweep is a cartesian grid of experiment points.  Not every axis costs the
same: some change the *traced program* (client count changes array shapes,
local steps K changes the inner scan length, the algorithm/topology/mixing
implementation change the graph) while others are just array or scalar
leaves of an otherwise identical program (the PRNG seed, the heterogeneity
level — it only shapes the data arrays — the noise scale, the stepsizes).

``GridSpec`` makes that distinction explicit: each :class:`Axis` is declared
**static** or **batchable**, and :meth:`GridSpec.cells` partitions the grid
into *static cells* — groups of points that share one compiled program and
differ only in batchable leaves.  ``repro.sweep.batched`` then runs each
cell as a single vmapped scan program over the stacked trajectory axis.

A batchable axis may still carry a ``cell_key``: a function of the value
whose *result* is a static program property even though the value itself is
a leaf.  The canonical case is sigma — the noise *scale* is a scalar leaf,
but whether noise ops exist in the graph at all (``sigma > 0``) is static,
so a sigma axis spanning zero declares ``cell_key=lambda s: s > 0`` and the
grid splits the noisy from the noise-free cells.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

KIND_STATIC = "static"
KIND_BATCH = "batch"


@dataclasses.dataclass(frozen=True)
class Axis:
    name: str
    values: Tuple[Any, ...]
    kind: str = KIND_BATCH
    # For batchable axes whose values imply a static program property
    # (see module docstring); the returned key joins the cell signature.
    cell_key: Optional[Callable[[Any], Any]] = None

    def __post_init__(self):
        if self.kind not in (KIND_STATIC, KIND_BATCH):
            raise ValueError(f"axis {self.name!r}: unknown kind {self.kind!r}")
        if not self.values:
            raise ValueError(f"axis {self.name!r}: empty values")


def static_axis(name: str, *values) -> Axis:
    return Axis(name=name, values=tuple(values), kind=KIND_STATIC)


def batch_axis(name: str, *values, cell_key=None) -> Axis:
    return Axis(name=name, values=tuple(values), kind=KIND_BATCH,
                cell_key=cell_key)


@dataclasses.dataclass(frozen=True)
class Cell:
    """One static cell: ``points`` share a compiled program; ``static`` is
    the axis assignment that identifies it (cell_key results included)."""
    key: str
    static: Dict[str, Any]
    points: Tuple[Dict[str, Any], ...]


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """A named sweep: ``base`` point parameters overlaid by the axes'
    cartesian product, optionally post-processed by ``derive`` (a function
    of the point returning parameter updates — e.g. the theory-prescribed
    ``eta ∝ 1/K`` coupling, or a topology-dependent eta_s).

    ``dedup=True`` drops points whose post-``derive`` parameters coincide
    (first occurrence wins) — for grids where an axis only applies to some
    values of another axis and ``derive`` pins it elsewhere (e.g. the churn
    grid's ``edge_prob``, read only by the erdos_renyi family): without
    dedup those cells would run bit-identical trajectories twice and count
    them as replicates.
    """
    name: str
    axes: Tuple[Axis, ...]
    base: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    derive: Optional[Callable[[Dict[str, Any]], Dict[str, Any]]] = None
    dedup: bool = False

    def __post_init__(self):
        names = [a.name for a in self.axes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate axis names in {self.name!r}: {names}")

    def points(self) -> List[Dict[str, Any]]:
        """All grid points in deterministic (row-major over axes) order."""
        pts = []
        for combo in itertools.product(*(a.values for a in self.axes)):
            p = dict(self.base)
            p.update({a.name: v for a, v in zip(self.axes, combo)})
            if self.derive is not None:
                p.update(self.derive(p))
            pts.append(p)
        if self.dedup:
            seen = set()
            unique = []
            for p in pts:
                k = point_key(p)
                if k not in seen:
                    seen.add(k)
                    unique.append(p)
            pts = unique
        return pts

    def cells(self) -> List[Cell]:
        """Partition :meth:`points` into static cells, order-preserving."""
        def signature(p):
            sig = []
            for a in self.axes:
                if a.kind == KIND_STATIC:
                    sig.append((a.name, p[a.name]))
                elif a.cell_key is not None:
                    sig.append((a.name, a.cell_key(p[a.name])))
            return tuple(sig)

        groups: Dict[tuple, List[dict]] = {}
        for p in self.points():
            groups.setdefault(signature(p), []).append(p)
        cells = []
        for sig, pts in groups.items():
            static = dict(sig)
            key = ",".join(f"{k}={v}" for k, v in sig) or "all"
            cells.append(Cell(key=key, static=static, points=tuple(pts)))
        return cells

    def to_json(self) -> dict:
        """Provenance-grade description (callables reduced to names)."""
        return {
            "name": self.name,
            "base": dict(self.base),
            "axes": [
                {"name": a.name, "kind": a.kind, "values": list(a.values),
                 **({"cell_key": getattr(a.cell_key, "__name__", "lambda")}
                    if a.cell_key is not None else {})}
                for a in self.axes
            ],
            **({"derive": getattr(self.derive, "__name__", "lambda")}
               if self.derive is not None else {}),
        }


def point_key(point: Mapping[str, Any]) -> str:
    """Deterministic ``k=v`` identity of a point — the store's merge key."""
    return ",".join(f"{k}={point[k]}" for k in sorted(point))


def config_hash(obj: Any) -> str:
    """Short stable hash of a JSON-serializable object (provenance)."""
    blob = json.dumps(obj, sort_keys=True, default=str).encode()
    return hashlib.sha1(blob).hexdigest()[:12]
