"""Sweep runner + CLI: drive whole hyperparameter grids as compiled cells.

A *point* is one experiment configuration — the kwargs of the historical
``benchmarks.common.run_to_epsilon`` (synthetic NC-SC quadratic, exact ∇Φ
oracle, rounds-to-ε on an ``eval_every`` grid).  :func:`run_point` executes
one point sequentially; :func:`run_cell` executes a whole static cell as a
single vmapped scan program (`repro.sweep.batched`), with one dispatch per
``eval_every`` chunk for the entire batch and the per-trajectory early-stop
mask freezing converged trajectories at exactly the boundary the sequential
``stop_fn`` would have stopped.  Both paths jit the *same* unbatched
trajectory program, so their trajectories are bit-identical
(tests/test_sweep.py holds every cell of small grids to that).

  PYTHONPATH=src python -m repro.sweep.run smoke           # tiny end-to-end
  PYTHONPATH=src python -m repro.sweep.run local_steps topology
  PYTHONPATH=src python -m repro.sweep.run --list
"""
from __future__ import annotations

import dataclasses
import os
import sys
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engine_lib
from repro.configs.base import AlgorithmConfig
from repro.core import (
    init_state,
    make_quadratic_data,
    make_round_step,
    mixing_matrix,
    point_etas,
    quadratic_cell_problem,
    sparse_mixing_matrix,
)
from repro.sweep import batched as batched_lib
from repro.sweep import cache as cache_lib
from repro.sweep import grid as grid_lib
from repro.sweep import store as store_lib

DX, DY = 10, 5  # the benchmarks' quadratic geometry (benchmarks.common)

# One-configuration defaults == run_to_epsilon's signature defaults.
# topology_family/edge_prob/client_drop_prob/participation are the churn
# axes (repro.core.stochastic_topology): family "static" + participation 1.0
# is the historical fixed-W full-participation point.
DEFAULT_POINT: Dict[str, Any] = dict(
    n=8, K=4, sigma=0.1, heterogeneity=1.0, topology="ring",
    algorithm="kgt_minimax", eta_cx=0.01, eta_cy=0.1, eta_s=0.5,
    eps=0.3, max_rounds=2000, seed=0, mixing_impl="dense", eval_every=10,
    topology_family="static", edge_prob=0.5, client_drop_prob=0.3,
    participation=1.0,
    num_byzantine=0, attack="honest", attack_scale=1.0, robust_trim=1,
    gossip_compress=None,
)

# Point parameters that change the traced program: same-valued across every
# point of a cell, enforced at cell build time.  (sigma is special-cased:
# its *value* is a leaf but sigma>0 toggles the noise ops — grid axes over
# sigma must declare ``cell_key=lambda s: s > 0``.  participation is the
# same shape: the rate is a leaf, but participation<1 toggles the mask ops —
# axes spanning 1.0 declare ``cell_key=lambda r: r < 1``.  num_byzantine is
# too: the count/attack id/scale are traced bundle leaves, but f>0 toggles
# the adversary extras slot — axes spanning 0 declare
# ``cell_key=lambda f: f > 0``.)
STATIC_KEYS = ("algorithm", "n", "K", "topology", "mixing_impl",
               "eps", "max_rounds", "eval_every", "topology_family",
               "robust_trim", "gossip_compress")


def _churn(p: Dict[str, Any]):
    """(samples W per round, applies a participation mask) — both static
    program properties of a cell."""
    return p["topology_family"] != "static", p["participation"] < 1.0


def _byz(p: Dict[str, Any]) -> bool:
    """Whether the cell carries the Byzantine adversary extras slot —
    a static program property (extras arity)."""
    return p["num_byzantine"] > 0


def _program_statics(p: Dict[str, Any], *, batched: bool) -> tuple:
    """The persistent-cache statics signature of a point's traced program —
    exactly the parameters baked into the jaxpr as constants or structure.
    Deliberately narrower than :data:`STATIC_KEYS`: ``eps`` is host-side
    and ``max_rounds``/``eval_every`` only choose operand values and chunk
    lengths (keyed separately), so cells differing only in those share
    executables."""
    return (
        ("algorithm", p["algorithm"]), ("n", p["n"]), ("K", p["K"]),
        ("topology", p["topology"]), ("mixing_impl", p["mixing_impl"]),
        ("topology_family", p["topology_family"]),
        ("robust_trim", p["robust_trim"]),
        ("gossip_compress", p["gossip_compress"]),
        ("noise", p["sigma"] > 0.0), ("churn", _churn(p)),
        ("byzantine", _byz(p)), ("batched", batched),
        ("geometry", (DX, DY)),
    )


def _full_point(p: Dict[str, Any]) -> Dict[str, Any]:
    full = dict(DEFAULT_POINT)
    unknown = set(p) - set(full)
    if unknown:
        raise ValueError(f"unknown point parameters {sorted(unknown)}")
    full.update(p)
    return full


def _cfg(p: Dict[str, Any]) -> AlgorithmConfig:
    return AlgorithmConfig(
        algorithm=p["algorithm"], num_clients=p["n"], local_steps=p["K"],
        eta_cx=p["eta_cx"], eta_cy=p["eta_cy"], eta_sx=p["eta_s"],
        eta_sy=p["eta_s"], topology=p["topology"],
        mixing_impl=p["mixing_impl"], robust_trim=p["robust_trim"],
        gossip_compress=p["gossip_compress"])


# Jitted per-point setup, cached on the static parameters it bakes in.
# Seed / heterogeneity / sigma are traced operands, so one compile serves
# every point of a cell (and any cell sharing the statics) — eager setup
# was ~2s/point of small-op dispatch, the dominant cost of small sweeps.
_PREPARERS: Dict[tuple, Any] = {}


def _preparer(p: Dict[str, Any]):
    noise = p["sigma"] > 0.0
    # gossip_compress changes the state *structure* (EF leaves), so it must
    # key the cached init program alongside the other structural statics
    cache_key = (p["n"], p["algorithm"], noise, p["gossip_compress"])
    if cache_key in _PREPARERS:
        return _PREPARERS[cache_key]
    problem = quadratic_cell_problem(DX, DY, mu=1.0, noise=noise)
    cfg = _cfg(p)  # init_state only reads algorithm/num_clients/dtype

    def prep(seed, het, sigma):
        key = jax.random.PRNGKey(seed)
        data = make_quadratic_data(key, p["n"], dx=DX, dy=DY,
                                   heterogeneity=het)
        cb = {k: v for k, v in data.items() if k != "mu"}
        if noise:
            cb = dict(cb, sigma=jnp.full((p["n"],), sigma, jnp.float32))
        st = init_state(problem, cfg, key, init_batch=cb,
                        init_keys=jax.random.split(key, p["n"]))
        consts = {
            "a_bar": data["A"].mean(0), "b_bar": data["B"].mean(0),
            "bv_bar": data["b"].mean(0), "q_bar": data["q"].mean(0),
        }
        return st, cb, consts

    _PREPARERS[cache_key] = jax.jit(prep)
    return _PREPARERS[cache_key]


def prepare_trajectory(p: Dict[str, Any], *, cache=None):
    """One point -> (Trajectories, phi-oracle constants).

    The historical ``run_to_epsilon`` recipe — data and problem from
    ``PRNGKey(seed)``, shared x0/y0, tracking corrections from the init
    batch — as one jitted program shared by the sequential and batched
    paths, so trajectory starts are bit-identical by construction.  The phi
    constants are the client-mean coefficients the exact ∇Φ oracle needs
    (the cell problem reads per-client slices from the batch and has no
    global view).  ``cache`` (a ``repro.sweep.cache.CompileCache``) serves
    the jitted setup program from the persistent executable cache — its
    statics are ``_PREPARERS``' key (seed/het/sigma are traced operands).
    """
    p = _full_point(p)
    prep = _preparer(p)
    args = (jnp.int32(p["seed"]), jnp.float32(p["heterogeneity"]),
            jnp.float32(p["sigma"]))
    if cache is not None:
        prep, _ = cache.get_or_compile(
            "preparer",
            (("n", p["n"]), ("algorithm", p["algorithm"]),
             ("noise", p["sigma"] > 0.0),
             ("gossip_compress", p["gossip_compress"]),
             ("geometry", (DX, DY))),
            prep, args)
    st, cb, consts = prep(*args)
    kb = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (p["K"], *v.shape)), cb)
    random_w, part = _churn(p)
    topo = None
    if random_w or part or _byz(p):
        from repro.core import adversary as adversary_lib

        topo = {"seed": jnp.int32(p["seed"]),
                "edge_prob": jnp.float32(p["edge_prob"]),
                "drop_prob": jnp.float32(p["client_drop_prob"]),
                "rate": jnp.float32(p["participation"]),
                "num_byzantine": jnp.int32(p["num_byzantine"]),
                "attack_id": jnp.int32(
                    adversary_lib.ATTACK_IDS[p["attack"]]),
                "attack_scale": jnp.float32(p["attack_scale"])}
    traj = batched_lib.Trajectories(
        state=st, batches=kb, etas=point_etas(_cfg(p)),
        seed=jnp.int32(p["seed"]), active=jnp.asarray(True), topo=topo)
    return traj, consts


def _phi_grad_norm(consts, x_clients, mu: float):
    """Exact ‖∇Φ(x̄)‖ from the client-mean constants — the expression of
    ``quadratic_problem.phi_grad`` + ``phi_grad_norm``, term for term."""
    x = x_clients.mean(0)
    ystar = (consts["b_bar"] @ x + consts["bv_bar"]) / mu
    g = consts["a_bar"] @ x + consts["q_bar"] + consts["b_bar"].T @ ystar
    return jnp.sqrt(jnp.sum(jnp.square(g)))


def _cell_programs(p: Dict[str, Any], *, batched: bool, mesh=None,
                   mesh_axis: str = batched_lib.CLIENTS):
    """(chunk builder, eval fn) for a cell whose static parameters are
    ``p``'s.  ``batched`` selects vmap-of-the-trajectory-program vs the
    unbatched sequential reference — the *only* difference between the two
    execution paths.

    The ∇Φ convergence oracle is deliberately a single-trajectory program
    on both paths: XLA's fusion of this small matvec chain is not
    vmap-rounding-stable (an ulp here flips a ``g < eps`` stop decision
    near the threshold), so the batched driver dispatches the same cached
    executable per active trajectory at chunk boundaries instead of
    vmapping it.  The scan chunk — where the round compute lives — stays
    one dispatch for the whole batch, and *is* bit-stable under vmap
    (held to that by tests/test_sweep.py).
    """
    noise = p["sigma"] > 0.0
    problem = quadratic_cell_problem(DX, DY, mu=1.0, noise=noise)
    random_w, part = _churn(p)
    byz = _byz(p)
    round_step = make_round_step(problem, _cfg(p), traced_etas=True,
                                 traced_w=random_w, participation=part,
                                 byzantine=byz)
    if random_w or part or byz:
        if p["mixing_impl"].startswith("sparse_"):
            # the W extras slot carries a SparseTopology pytree — the draw
            # happens on the neighbor lists of the configured support graph,
            # never through an (n, n) array
            support = sparse_mixing_matrix(p["topology"], p["n"])
            sampler = batched_lib.make_churn_traj_sampler(
                local_steps=p["K"], num_clients=p["n"],
                family=p["topology_family"], participation=part,
                sparse_support=support, byzantine=byz)
        else:
            base_w = (mixing_matrix(p["topology"], p["n"])
                      if p["topology_family"] in ("static", "dropout")
                      else None)
            sampler = batched_lib.make_churn_traj_sampler(
                local_steps=p["K"], num_clients=p["n"],
                family=p["topology_family"], base_w=base_w,
                participation=part, byzantine=byz)
    else:
        sampler = batched_lib.make_quadratic_traj_sampler(
            local_steps=p["K"], num_clients=p["n"])
    if batched:
        build = batched_lib.make_batched_chunk_builder(
            round_step, sampler, mesh=mesh, mesh_axis=mesh_axis)
    else:
        build = batched_lib.make_trajectory_chunk_builder(round_step, sampler)
    eval_fn = jax.jit(lambda c, x: _phi_grad_norm(c, x, 1.0))
    return build, eval_fn


def _timed_eval(eval_fn, *, cache=None, statics=None, telemetry=None):
    """AOT-compile ``eval_fn`` on first use, reporting the compile seconds
    (same split discipline as ``engine.timed_chunk_builder``).  With a
    ``cache`` the executable is served from/stored to the persistent
    compile cache under kind ``"phi_eval"``.

    A failed AOT compile falls back to the on-demand jit — loudly (stderr +
    an ``eval_aot_fallback`` telemetry counter), and *without* charging the
    failed attempt to ``compile_s``: the on-demand path re-traces inside the
    first real call, so attributing the aborted lower() time would
    double-count against ``run_s``.
    """
    holder: dict = {}

    def call(*args):
        if "fn" not in holder:
            if cache is not None:
                fn, info = cache.get_or_compile("phi_eval", statics,
                                                eval_fn, args)
                holder["fn"] = fn
                holder["compile_s"] = (info["compile_s"]
                                       + info["deserialize_s"])
            else:
                t0 = time.perf_counter()
                try:
                    holder["fn"] = eval_fn.lower(*args).compile()
                    holder["compile_s"] = time.perf_counter() - t0
                except Exception as e:
                    holder["fn"] = eval_fn
                    holder["compile_s"] = 0.0
                    print(f"[sweep] eval AOT compile failed "
                          f"({type(e).__name__}: {e}); falling back to "
                          "on-demand jit", file=sys.stderr, flush=True)
                    if telemetry is not None:
                        telemetry.counter("eval_aot_fallback", 1,
                                          error=type(e).__name__)
        return holder["fn"](*args)

    call.stats = holder
    return call


def _timing_split(wall: float, compile_s: float, setup_s: float) -> dict:
    """The ``{wall_s, compile_s, setup_s, run_s}`` record with the engine's
    rounding discipline: ms-grained, and ``run_s`` clamped at zero — the
    subtraction runs over three separately-measured intervals, so rounding
    jitter (or a cache making compile_s ≈ wall) must not surface as a
    negative runtime."""
    return {"wall_s": round(wall, 3), "compile_s": round(compile_s, 3),
            "setup_s": round(setup_s, 3),
            "run_s": max(0.0, round(wall - compile_s - setup_s, 3))}


def _chunk_lengths(length: int, cache) -> tuple:
    """The sub-chunk schedule for one ``eval_every`` interval: the
    power-of-two bucket decomposition when a cache wants length sharing
    (bit-exact — scan chunks compose through the carried state), the plain
    length otherwise."""
    if cache is not None and cache.bucket_lengths:
        return cache_lib.length_schedule(length)
    return (length,)


def run_point(p: Dict[str, Any], *, cache=cache_lib.UNSET, telemetry=None):
    """Sequential reference: one point, engine-chunked scan per
    ``eval_every`` interval, ∇Φ checked at chunk boundaries with immediate
    stop — the execution `benchmarks.common.run_to_epsilon` delegates to.

    Returns ``(rounds_to_eps or None, final ‖∇Φ‖, timing, history)`` where
    ``timing = {"wall_s", "compile_s", "setup_s", "run_s"}`` splits XLA
    compilation from steady-state execution and ``history`` is
    ``[(round, grad), …]`` on the evaluation grid.

    ``cache`` is a ``repro.sweep.cache.CompileCache`` (default: resolved
    from ``$REPRO_COMPILE_CACHE``; ``None`` disables): the setup, chunk,
    and eval executables are served from disk when warm, and chunk lengths
    are served from the shared power-of-two pool.
    """
    p = _full_point(p)
    cache = cache_lib.resolve(cache, telemetry)
    t0 = time.perf_counter()
    traj, consts = prepare_trajectory(p, cache=cache)
    jax.block_until_ready(traj.state.x)
    setup_s = time.perf_counter() - t0
    statics = _program_statics(p, batched=False)
    build_raw, eval_raw = _cell_programs(p, batched=False)
    build = engine_lib.timed_chunk_builder(build_raw, cache=cache,
                                           statics=statics)
    eval_fn = _timed_eval(eval_raw, cache=cache,
                          statics=(("kind", "phi"), ("geometry", (DX, DY)),
                                   ("n", p["n"])),
                          telemetry=telemetry)
    hist: List[tuple] = []
    hit = None
    final_round = jnp.int32(p["max_rounds"] - 1)
    r = 0
    while r < p["max_rounds"]:
        length = min(p["eval_every"], p["max_rounds"] - r)
        for sub in _chunk_lengths(length, cache):
            traj, _ = build(sub)(traj, final_round)
        r += length
        g = float(eval_fn(consts, traj.state.x))
        hist.append((r, g))
        if g < p["eps"]:
            hit = r
            break
    final = hist[-1][1] if hist else float("nan")
    wall = time.perf_counter() - t0
    compile_s = build.stats["compile_s"] + eval_fn.stats.get("compile_s", 0.0)
    timing = _timing_split(wall, compile_s, setup_s)
    return hit, final, timing, hist


def run_cell(cell: grid_lib.Cell, *, mesh=None,
             mesh_axis: str = batched_lib.CLIENTS,
             return_trajs: bool = False, cache=cache_lib.UNSET,
             telemetry=None):
    """One static cell as a batched program: returns
    ``(per-point result dicts, timing)`` — with ``return_trajs``,
    ``((results, timing), trajectories)`` including the final stacked
    (frozen-where-converged) state.

    Drives the same evaluation grid as :func:`run_point`: after each
    ``eval_every`` chunk the batched ∇Φ oracle runs once for all B
    trajectories, newly-converged ones record their hit round and drop out
    of the ``active`` mask (their state freezes at this exact boundary),
    and the loop exits early once every trajectory has converged.

    With a compile ``cache`` (default: ``$REPRO_COMPILE_CACHE``) the cell's
    executables persist across processes, and the trajectory batch is
    padded up to its :func:`repro.sweep.cache.bucket_batch` bucket with
    ``active=False`` clones of trajectory 0, so cells differing only in
    point count share one vmapped program — real rows are bit-identical
    (vmap slice stability, pinned by tests) and results are sliced back to
    the real batch.  Under a ``mesh`` the AOT/bucket layers are skipped
    (padding would change the sharding divisibility and serialized
    executables embed their device assignment); jax's own persistent cache
    (layer 1) still applies.
    """
    points = [_full_point(p) for p in cell.points]
    p0 = points[0]
    for p in points[1:]:
        bad = [k for k in STATIC_KEYS if p[k] != p0[k]]
        if (p["sigma"] > 0.0) != (p0["sigma"] > 0.0):
            bad.append("sigma>0")
        if _churn(p) != _churn(p0):
            bad.append("participation<1")
        if _byz(p) != _byz(p0):
            bad.append("num_byzantine>0")
        if bad:
            raise ValueError(
                f"cell {cell.key!r} mixes static program parameters {bad}; "
                "declare them as static axes (or give the sigma axis "
                "cell_key=lambda s: s > 0, a participation axis spanning "
                "1.0 cell_key=lambda r: r < 1)")

    cache = cache_lib.resolve(cache, telemetry)
    if mesh is not None:
        cache = None  # layer 1 (jax's own cache) still applies
    t0 = time.perf_counter()
    prepared = [prepare_trajectory(p, cache=cache) for p in points]
    trajs = batched_lib.tree_stack([tr for tr, _ in prepared])
    consts = [c for _, c in prepared]  # per-trajectory, never stacked
    B = len(points)
    pad = 0
    if cache is not None and cache.bucket_batch:
        pad = cache_lib.bucket_batch(B) - B
        trajs = cache_lib.pad_trajectories(trajs, pad)
    jax.block_until_ready(trajs.state.x)
    setup_s = time.perf_counter() - t0
    if mesh is not None:
        trajs = jax.device_put(trajs, batched_lib.batch_sharding(mesh, mesh_axis))
    build_raw, eval_raw = _cell_programs(p0, batched=True, mesh=mesh,
                                         mesh_axis=mesh_axis)
    build = engine_lib.timed_chunk_builder(
        build_raw, cache=cache, statics=_program_statics(p0, batched=True))
    eval_fn = _timed_eval(eval_raw, cache=cache,
                          statics=(("kind", "phi"), ("geometry", (DX, DY)),
                                   ("n", p0["n"])),
                          telemetry=telemetry)

    active = np.ones(B, bool)
    hit: List[Optional[int]] = [None] * B
    hist: List[List[tuple]] = [[] for _ in range(B)]
    final_round = jnp.int32(p0["max_rounds"] - 1)

    def full_mask(live):
        # padding rows stay frozen (False) for the whole run
        return jnp.asarray(np.concatenate([live, np.zeros(pad, bool)])
                           if pad else live)

    r = 0
    while r < p0["max_rounds"]:
        length = min(p0["eval_every"], p0["max_rounds"] - r)
        for sub in _chunk_lengths(length, cache):
            trajs, _ = build(sub)(trajs, final_round)
        r += length
        # dispatch the oracle for every live trajectory, then sync once
        g = {i: eval_fn(consts[i], trajs.state.x[i])
             for i in range(B) if active[i]}
        for i, gi in g.items():
            gi = float(gi)
            hist[i].append((r, gi))
            if gi < points[i]["eps"]:
                hit[i] = r
                active[i] = False
        if not active.any():
            break
        trajs = dataclasses.replace(trajs, active=full_mask(active))

    wall = time.perf_counter() - t0
    compile_s = build.stats["compile_s"] + eval_fn.stats.get("compile_s", 0.0)
    timing = _timing_split(wall, compile_s, setup_s)
    results = [
        {"rounds_to_eps": hit[i],
         "final_grad": hist[i][-1][1] if hist[i] else float("nan"),
         "history": hist[i]}
        for i in range(B)
    ]
    if return_trajs:
        if pad:
            trajs = jax.tree.map(lambda x: x[:B], trajs)
        return (results, timing), trajs
    return results, timing


def cell_comm(p0: Dict[str, Any]):
    """The analytic per-round communication of a cell's static lowering
    (``repro.obs.ledger``) — the quadratic workload's packed dims are the
    problem geometry (DX, DY)."""
    from repro import obs

    p0 = _full_point(p0)
    return obs.round_comm(
        mixing_impl=p0["mixing_impl"], n=p0["n"], dims=(DX, DY),
        topology=p0["topology"],
        track=p0["algorithm"] in ("kgt_minimax", "gt_gda"),
        gossip_compress=p0["gossip_compress"])


def run_sweep(spec: grid_lib.GridSpec, *, mesh=None, store: bool = True,
              store_dir: Optional[str] = None, csv=None,
              telemetry=None, cache=cache_lib.UNSET) -> dict:
    """Run every static cell of ``spec`` batched; persist and return
    ``{"points": {point_key: {...}}, "cells": {cell_key: {...}}}``.

    Each cell record carries, alongside the compile/run timing split, a
    ``comm`` block — the communication ledger's analytic bytes/round for
    the cell's lowering and the total bytes its trajectories moved — so the
    stored sweep answers the paper's communication-efficiency question
    directly.  ``telemetry`` (a ``repro.obs.Telemetry``) additionally gets
    a per-cell span and ledger event, plus the cache's ``compile_cache.*``
    counters when the persistent compile cache is active; the cache's
    stats snapshot is stamped into the stored sweep's provenance.
    """
    from repro import obs

    tel = telemetry if telemetry is not None else obs.NULL
    cache = cache_lib.resolve(cache, telemetry)
    out: dict = {"name": spec.name, "points": {}, "cells": {}}
    for cell in spec.cells():
        with tel.span("cell", sweep=spec.name, cell=cell.key,
                      points=len(cell.points)):
            results, timing = run_cell(cell, mesh=mesh, cache=cache,
                                       telemetry=telemetry)
        ledger = obs.CommLedger(cell_comm(cell.points[0]))
        # rounds actually executed: each trajectory ran to its last
        # evaluation boundary (hit or max_rounds)
        cell_rounds = sum(res["history"][-1][0] if res["history"] else 0
                          for res in results)
        ledger.add_rounds(cell_rounds)
        tel.emit(ledger.event(rounds=cell_rounds, sweep=spec.name,
                              cell=cell.key))
        out["cells"][cell.key] = {
            "static": cell.static, "num_trajectories": len(cell.points),
            **timing,
            "comm": {**ledger.describe(), "rounds": cell_rounds,
                     "bytes_total": ledger.total_bytes}}
        if csv is not None:
            csv(f"sweep,{spec.name},cell={cell.key},B={len(cell.points)},"
                f"compile_s={timing['compile_s']},run_s={timing['run_s']},"
                f"comm_bytes_per_round={ledger.bytes_per_round}")
        for p, res in zip(cell.points, results):
            out["points"][grid_lib.point_key(p)] = {
                "params": dict(p), "cell": cell.key, **res}
    if cache is not None:
        out["compile_cache"] = cache.describe()
    if store:
        path = store_lib.save(
            spec.name, out, spec, directory=store_dir,
            extra_provenance=(
                {"compile_cache": cache.describe()} if cache is not None
                else None))
        out["store_path"] = path
    return out


def points_where(result: dict, **params) -> List[dict]:
    """Stored/returned points whose params match ``params`` (sweep order)."""
    return [rec for rec in result["points"].values()
            if all(rec["params"].get(k) == v for k, v in params.items())]


def summarize(points: List[dict]) -> dict:
    """mean±std over a replicate group (seeds): final grad + rounds-to-ε
    over the converged subset, plus the hit rate."""
    finals = [p["final_grad"] for p in points]
    hits = [p["rounds_to_eps"] for p in points if p["rounds_to_eps"] is not None]
    out = {
        "num": len(points),
        "final_grad_mean": float(np.mean(finals)) if finals else None,
        "final_grad_std": float(np.std(finals)) if finals else None,
        "hit_rate": len(hits) / len(points) if points else None,
    }
    if hits:
        out["rounds_to_eps_mean"] = float(np.mean(hits))
        out["rounds_to_eps_std"] = float(np.std(hits))
    else:
        out["rounds_to_eps_mean"] = None
        out["rounds_to_eps_std"] = None
    return out


def main() -> None:
    import argparse

    from repro.sweep import defs

    ap = argparse.ArgumentParser(
        description="Run named experiment sweeps as batched compiled cells")
    ap.add_argument("names", nargs="*", help="sweep names (see --list)")
    ap.add_argument("--list", action="store_true", help="list known sweeps")
    ap.add_argument("--out", default=None, help="store directory "
                    "(default: <repo>/results/sweeps)")
    ap.add_argument("--cache-dir", default=None, help="persistent compile "
                    "cache root (default: $REPRO_COMPILE_CACHE, else "
                    "<repo>/results/.xla_cache)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the persistent compile cache")
    args = ap.parse_args()
    if args.list or not args.names:
        for name, spec in sorted(defs.SWEEPS.items()):
            cells = spec.cells()
            npts = sum(len(c.points) for c in cells)
            print(f"{name}: {npts} points in {len(cells)} cells")
        return
    if args.no_cache:
        cache = None
    elif args.cache_dir is not None:
        cache_lib.enable_xla_cache(os.path.join(args.cache_dir, "xla"))
        cache = cache_lib.CompileCache(os.path.join(args.cache_dir, "aot"))
    else:
        # CLI default is cache ON unless the env says otherwise
        cache = cache_lib.from_env()
        if cache is None and os.environ.get(cache_lib.ENV_CACHE) is None:
            cache_lib.enable_xla_cache()
            cache = cache_lib.CompileCache()
    for name in args.names:
        spec = defs.SWEEPS[name]
        t0 = time.perf_counter()
        res = run_sweep(spec, store_dir=args.out, csv=print, cache=cache)
        print(f"sweep,{name},points={len(res['points'])},"
              f"cells={len(res['cells'])},wall_s={time.perf_counter()-t0:.1f},"
              f"store={res.get('store_path')}")
        if cache is not None:
            s = cache.stats
            print(f"sweep,{name},cache_hits={int(s['hits'])},"
                  f"cache_misses={int(s['misses'])},"
                  f"cache_memo_hits={int(s['memo_hits'])},"
                  f"cache_errors={int(s['errors'])},"
                  f"cache_bytes_written={int(s['bytes_written'])}")


if __name__ == "__main__":
    main()
