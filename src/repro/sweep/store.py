"""Persistence for sweep results: ``results/sweeps/<name>.json``.

Same merge-don't-clobber contract as ``benchmarks/run.py``: a partial rerun
(one cell in CI, a few added seeds) updates its own points and leaves the
rest of the file intact.  Every save restamps ``provenance`` — grid
description + config hash, compile vs run seconds, jax/device info, git
commit, timestamp — so a stored figure is reproducible from the file alone.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
from typing import Any, Optional

from repro.sweep import grid as grid_lib


def repo_root() -> str:
    """The checkout root (this file lives at src/repro/sweep/store.py)."""
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


def default_dir() -> str:
    return os.path.join(repo_root(), "results", "sweeps")


def git_commit() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=repo_root(),
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        return None


def device_info() -> str:
    import jax

    d = jax.devices()[0]
    kind = getattr(d, "device_kind", "") or ""
    return f"{d.platform}:{kind}" if kind else d.platform


def provenance(spec: Optional[grid_lib.GridSpec] = None, **extra) -> dict:
    import jax

    from repro import obs

    out = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "jax": jax.__version__,
        "device": device_info(),
        "git_commit": git_commit(),
        "telemetry_version": obs.TELEMETRY_VERSION,
        "ledger_version": obs.LEDGER_VERSION,
    }
    if spec is not None:
        gj = spec.to_json()
        out["grid"] = gj
        out["config_hash"] = grid_lib.config_hash(gj)
    out.update(extra)
    return out


def _jsonable(obj: Any):
    """numpy scalars/arrays -> plain python, recursively."""
    import numpy as np

    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _jsonable(obj.tolist())
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def save(name: str, result: dict, spec: Optional[grid_lib.GridSpec] = None,
         directory: Optional[str] = None,
         extra_provenance: Optional[dict] = None) -> str:
    """Merge ``result`` (``{"points": ..., "cells": ...}``) into the named
    store file and return its path.  ``extra_provenance`` keys (e.g. the
    compile cache's ``describe()`` snapshot) are merged into the restamped
    ``provenance`` block."""
    directory = directory or default_dir()
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"{name}.json")
    merged: dict = {"name": name, "points": {}, "cells": {}}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if isinstance(prev, dict):
                merged["points"] = prev.get("points", {})
                merged["cells"] = prev.get("cells", {})
        except (OSError, ValueError):
            pass
    merged["points"].update(_jsonable(result.get("points", {})))
    merged["cells"].update(_jsonable(result.get("cells", {})))
    merged["provenance"] = _jsonable(provenance(spec,
                                                **(extra_provenance or {})))
    with open(path, "w") as f:
        json.dump(merged, f, indent=1, default=str)
    return path


def load(name: str, directory: Optional[str] = None) -> Optional[dict]:
    path = os.path.join(directory or default_dir(), f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)
