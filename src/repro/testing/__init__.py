"""Test-support utilities shipped with the package.

``minihypothesis`` — a tiny, dependency-free stand-in for the subset of the
`hypothesis` API the property suite uses, so ``tests/test_property.py``
*runs* (0 skips) in hermetic environments where the real library cannot be
installed.  CI installs the real thing via the ``[dev]`` extra; see
``tests/_hyp.py`` for the selection shim.
"""
