"""A minimal, dependency-free stand-in for the ``hypothesis`` API surface
this repo's property tests use.

Purpose: the property suite (`tests/test_property.py`) encodes the
system's load-bearing invariants — Σ_i c_i = 0, W-independent mean
dynamics, kernel/oracle parity — and silently skipping it wherever
``hypothesis`` isn't installed (hermetic CI containers, offline dev boxes)
means those invariants go unchecked exactly where regressions land.  This
module lets the suite *run everywhere*: the real library when available
(the ``[dev]`` extra installs it), this fallback otherwise (``tests/_hyp.py``
selects).

What it implements: ``@given(**strategies)``, ``@settings(max_examples=…,
deadline=…)`` (other settings accepted and ignored), and the strategies
``integers``, ``floats``, ``booleans``, ``sampled_from``, ``just``.  What
it deliberately does not: shrinking, the example database, stateful
testing, health checks, ``assume``-driven rejection sampling.

Determinism: each test runs ``max_examples`` examples — first the corner
cases of every strategy (bounds, both booleans, every sampled value in
order), then pseudo-random draws seeded from the test's qualified name and
the example index.  Failures therefore reproduce run-to-run and the
failing example's kwargs appear in the assertion context chained onto the
original error.
"""
from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable, Sequence

DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    """Draw protocol: ``corners()`` lists must-try values (may be empty),
    ``draw(rng)`` produces one pseudo-random value."""

    def corners(self) -> list:
        return []

    def draw(self, rng: random.Random):
        raise NotImplementedError

    def __or__(self, other):
        return _OneOf((self, other))


class _Integers(Strategy):
    def __init__(self, min_value: int, max_value: int):
        if min_value > max_value:
            raise ValueError(f"integers: empty range [{min_value}, {max_value}]")
        self.lo, self.hi = int(min_value), int(max_value)

    def corners(self):
        return [self.lo] if self.lo == self.hi else [self.lo, self.hi]

    def draw(self, rng):
        return rng.randint(self.lo, self.hi)


class _Floats(Strategy):
    def __init__(self, min_value: float, max_value: float):
        if min_value > max_value:
            raise ValueError(f"floats: empty range [{min_value}, {max_value}]")
        self.lo, self.hi = float(min_value), float(max_value)

    def corners(self):
        return [self.lo] if self.lo == self.hi else [self.lo, self.hi]

    def draw(self, rng):
        return rng.uniform(self.lo, self.hi)


class _Booleans(Strategy):
    def corners(self):
        return [False, True]

    def draw(self, rng):
        return bool(rng.getrandbits(1))


class _SampledFrom(Strategy):
    def __init__(self, values: Sequence[Any]):
        self.values = list(values)
        if not self.values:
            raise ValueError("sampled_from: empty collection")

    def corners(self):
        return list(self.values)

    def draw(self, rng):
        return rng.choice(self.values)


class _Just(Strategy):
    def __init__(self, value):
        self.value = value

    def corners(self):
        return [self.value]

    def draw(self, rng):
        return self.value


class _OneOf(Strategy):
    def __init__(self, options: Sequence[Strategy]):
        self.options = list(options)

    def corners(self):
        return [c for s in self.options for c in s.corners()]

    def draw(self, rng):
        return rng.choice(self.options).draw(rng)


class _StrategiesNamespace:
    """Mimics ``from hypothesis import strategies as st`` usage."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value: float, max_value: float, **_ignored) -> Strategy:
        return _Floats(min_value, max_value)

    @staticmethod
    def booleans() -> Strategy:
        return _Booleans()

    @staticmethod
    def sampled_from(values: Sequence[Any]) -> Strategy:
        return _SampledFrom(values)

    @staticmethod
    def just(value) -> Strategy:
        return _Just(value)

    @staticmethod
    def one_of(*options: Strategy) -> Strategy:
        return _OneOf(options)


strategies = _StrategiesNamespace()


def settings(**kwargs) -> Callable:
    """Decorator recording settings (only ``max_examples`` is honored;
    ``deadline`` & co. are accepted for API compatibility).  Works above or
    below ``@given`` — ``functools.wraps`` propagates the attribute up and
    ``given``'s wrapper reads it lazily at call time."""

    def decorate(fn):
        fn._mh_settings = dict(kwargs)
        return fn

    return decorate


def given(**param_strategies: Strategy) -> Callable:
    """Decorator running the test over deterministic example draws."""
    for name, strat in param_strategies.items():
        if not isinstance(strat, Strategy):
            raise TypeError(f"given({name}=...): not a strategy: {strat!r}")
    names = sorted(param_strategies)

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_mh_settings", {})
            max_examples = int(conf.get("max_examples", DEFAULT_MAX_EXAMPLES))
            corner_lists = {k: param_strategies[k].corners() for k in names}
            for idx in range(max_examples):
                rng = random.Random(
                    f"{fn.__module__}.{getattr(fn, '__qualname__', fn.__name__)}"
                    f":{idx}")
                example = {}
                for k in names:
                    cs = corner_lists[k]
                    example[k] = (cs[idx] if idx < len(cs)
                                  else param_strategies[k].draw(rng))
                try:
                    fn(*args, **example, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"minihypothesis: falsifying example #{idx}: "
                        f"{example}") from e

        # pytest must not mistake the strategy parameters for fixtures: hide
        # the wrapped signature (functools.wraps exposes it via __wrapped__)
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper.__signature__ = inspect.Signature()
        wrapper.is_hypothesis_test = True  # what real hypothesis marks
        return wrapper

    return decorate
