"""Shared hypothesis import for the property suite.

The real library when installed (``pip install -e '.[dev]'`` — what CI
does), else the bundled deterministic fallback
(``repro.testing.minihypothesis``), so the property tests always *run* —
``pytest -q tests/test_property.py`` must report 0 skipped in every
environment.  Test modules import ``given``/``settings``/``st`` from here
and must stay within the API subset the fallback implements (integers,
floats, booleans, sampled_from, just, one_of).  One more restriction: the
fallback's ``@given`` exposes a zero-argument signature to pytest, so do
NOT combine it with pytest fixtures or ``@pytest.mark.parametrize`` on the
same test — that works under real hypothesis but fails collection here;
fold the extra axis into a strategy instead.
"""
try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    USING_REAL_HYPOTHESIS = True
except ImportError:  # hermetic/offline environment
    from repro.testing.minihypothesis import given, settings  # noqa: F401
    from repro.testing.minihypothesis import strategies as st  # noqa: F401

    USING_REAL_HYPOTHESIS = False
