"""Byzantine adversary axis: attack semantics, robust aggregation parity
against the kernels.ref oracle, round-step invariants under attack, and the
headline divergence witness (plain gossip dies, trimmed mean survives)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AlgorithmConfig
from repro.core import (
    ATTACK_IDS,
    ATTACKS,
    Adversary,
    apply_attack,
    attack_ids,
    diagnostics,
    init_state,
    make_attack_sampler,
    make_quadratic_data,
    make_round_step,
    mixing_matrix,
    quadratic_problem,
)
from repro.core import adversary as adversary_lib
from repro.core import sparse_topology as sparse_lib
from repro.core import stochastic_topology as stoch
from repro.core.mixing import (
    ROBUST_RULES,
    _robust_reduce,
    robust_mix_dense,
    robust_mix_sparse,
)
from repro.kernels.ref import robust_agg_ref


# ---------------------------------------------------------------------------
# attack semantics
# ---------------------------------------------------------------------------

def test_attack_ids_prefix():
    ids = np.asarray(attack_ids(6, 2, ATTACK_IDS["sign_flip"]))
    np.testing.assert_array_equal(ids, [1, 1, 0, 0, 0, 0])
    assert ids.dtype == np.int32


def _adv(ids, scale=1.0, seed=0):
    return Adversary(ids=jnp.asarray(ids, jnp.int32),
                     key=jax.random.PRNGKey(seed),
                     scale=jnp.float32(scale))


def test_apply_attack_per_row_semantics():
    n, d = 5, 7
    x = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    tree = {"a": x}
    adv = _adv([0, 1, 2, 3, 0], scale=2.0)
    out = apply_attack(adv, tree)["a"]
    # honest rows bit-untouched even with every attack id present
    np.testing.assert_array_equal(out[0], x[0])
    np.testing.assert_array_equal(out[4], x[4])
    np.testing.assert_allclose(out[1], -2.0 * x[1], rtol=1e-6)
    np.testing.assert_allclose(
        out[2], np.full(d, adversary_lib.LARGE_NORM * 2.0), rtol=1e-6)
    # random_noise: deterministic in the adversary key, not a copy of x
    out2 = apply_attack(adv, tree)["a"]
    np.testing.assert_array_equal(out[3], out2[3])
    assert not np.allclose(out[3], x[3])


def test_apply_attack_streams_and_leaves_draw_disjoint_noise():
    n, d = 3, 16
    x = jnp.zeros((n, d))
    adv = _adv([3, 3, 3], scale=1.0)
    a = apply_attack(adv, {"u": x, "v": x}, stream=0)
    b = apply_attack(adv, {"u": x, "v": x}, stream=1)
    # different leaves of one call and the same leaf across streams (Δx vs
    # Δy) must not share noise
    assert not np.allclose(a["u"], a["v"])
    assert not np.allclose(a["u"], b["u"])


def test_all_honest_adversary_is_bitwise_identity():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 2))
    out = apply_attack(_adv([0, 0, 0, 0], scale=9.0), {"t": x})["t"]
    np.testing.assert_array_equal(out, x)


def test_make_attack_sampler_fold_in_determinism():
    fn = make_attack_sampler(4, jax.random.PRNGKey(7), num_byzantine=1,
                             attack="random_noise", scale=0.5)
    a, b = fn(jnp.int32(12)), fn(jnp.int32(12))
    np.testing.assert_array_equal(a.key, b.key)
    np.testing.assert_array_equal(a.ids, b.ids)
    assert not np.array_equal(np.asarray(a.key), np.asarray(fn(jnp.int32(13)).key))
    with pytest.raises(ValueError, match="unknown attack"):
        make_attack_sampler(4, jax.random.PRNGKey(0), num_byzantine=1,
                            attack="gaslight")


# ---------------------------------------------------------------------------
# robust aggregation vs the pure-jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", ROBUST_RULES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_robust_reduce_matches_oracle(rule, seed):
    """Implementation == kernels.ref oracle on random values, random valid
    masks, and injected non-finite entries (the oracle takes a deliberately
    different float path: nanmedian / descending sort)."""
    n, m, d = 6, 8, 11
    key = jax.random.PRNGKey(seed)
    vals = jax.random.normal(key, (n, m, d)) * 3.0
    # sprinkle NaN/±inf: a diverged attacker's contribution
    k1, k2 = jax.random.split(jax.random.fold_in(key, 1))
    vals = jnp.where(jax.random.uniform(k1, (n, m, d)) < 0.1, jnp.nan, vals)
    vals = jnp.where(jax.random.uniform(k2, (n, m, d)) < 0.05, jnp.inf, vals)
    valid = jax.random.uniform(jax.random.fold_in(key, 2), (n, m)) < 0.7
    # the self slot is always valid and finite (every row keeps ≥ 1)
    valid = valid.at[:, 0].set(True)
    vals = vals.at[:, 0, :].set(jax.random.normal(jax.random.fold_in(key, 3),
                                                  (n, d)))
    got = _robust_reduce(vals, valid, rule, 2)
    want = robust_agg_ref(vals, valid, rule=rule, trim=2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_nonfinite_candidate_does_not_consume_trim_slot():
    """A NaN/inf neighbor is invalid per coordinate — the b-trim stays
    symmetric over the finite values instead of permanently spending one
    top slot on the blown-up client (which would bias every honest mean)."""
    vals = jnp.asarray([[[jnp.inf], [1.0], [2.0], [3.0]]])   # (1, 4, 1)
    valid = jnp.ones((1, 4), bool)
    tm = _robust_reduce(vals, valid, "trimmed_mean", 1)
    np.testing.assert_allclose(tm, [[2.0]])                  # trims 1 and 3
    med = _robust_reduce(vals, valid, "coord_median", 1)
    np.testing.assert_allclose(med, [[2.0]])
    nanv = vals.at[0, 0, 0].set(jnp.nan)
    np.testing.assert_allclose(
        _robust_reduce(nanv, valid, "trimmed_mean", 1), [[2.0]])


@pytest.mark.parametrize("rule", ROBUST_RULES)
def test_robust_sparse_matches_dense_on_same_support(rule):
    n, d = 16, 9
    w = jnp.asarray(mixing_matrix("exp", n), jnp.float32)
    sp = sparse_lib.from_dense(np.asarray(w))
    buf = jax.random.normal(jax.random.PRNGKey(5), (n, d)) * 2.0
    buf = buf.at[3].set(jnp.inf)   # one blown-up client rides both forms
    dense = robust_mix_dense(buf, w, rule=rule, trim=1)
    sparse = robust_mix_sparse(buf, sp, rule=rule, trim=1)
    np.testing.assert_allclose(dense, sparse, rtol=1e-5, atol=1e-6)


def test_robust_median_ignores_one_outlier_exactly():
    """With a full support and one arbitrarily corrupted client, the
    coordinate median of n=5 equal honest values is the honest value."""
    n, d = 5, 4
    w = jnp.asarray(mixing_matrix("full", n), jnp.float32)
    buf = jnp.ones((n, d))
    buf = buf.at[0].set(-1e9)
    out = robust_mix_dense(buf, w, rule="coord_median", trim=1)
    np.testing.assert_allclose(out[1:], np.ones((n - 1, d)), rtol=1e-6)


# ---------------------------------------------------------------------------
# round-step invariants under attack
# ---------------------------------------------------------------------------

def _byz_setup(n=6, k=2, mixing_impl="dense", topology="ring", het=1.0):
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, n, dx=5, dy=3, heterogeneity=het)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(num_clients=n, local_steps=k, eta_cx=0.01,
                          eta_cy=0.05, eta_sx=0.4, eta_sy=0.4,
                          topology=topology, mixing_impl=mixing_impl)
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    stt = init_state(prob, cfg, key, init_batch=cb,
                     init_keys=jax.random.split(key, n))
    return prob, cfg, stt, kb


@pytest.mark.parametrize("mixing_impl", ["dense", "sparse_packed"])
@pytest.mark.parametrize("attack", ["sign_flip", "large_norm", "random_noise"])
def test_sum_c_zero_under_attack_linear_gossip(mixing_impl, attack):
    """The attacker follows the protocol with its corrupted Δ, so under any
    linear doubly stochastic W the Σ_i c_i = 0 telescoping survives every
    attack — an attacked Δ is still just a Δ."""
    n, k = 6, 2
    prob, cfg, stt, kb = _byz_setup(n=n, k=k, mixing_impl=mixing_impl)
    step = jax.jit(make_round_step(prob, cfg, byzantine=True))
    fn = make_attack_sampler(n, jax.random.PRNGKey(2), num_byzantine=2,
                             attack=attack, scale=2.0)
    for t in range(3):
        keys = jax.random.split(jax.random.PRNGKey(t), k * n).reshape(k, n, 2)
        stt = step(stt, kb, keys, fn(jnp.int32(t)))
    for c in (stt.cx, stt.cy):
        mean_c = jax.tree.leaves(jax.tree.map(lambda v: v.mean(0), c))[0]
        assert float(jnp.abs(mean_c).max()) < 1e-3


@pytest.mark.parametrize("mixing_impl", ["dense", "trimmed_mean",
                                         "sparse_coord_median"])
def test_inactive_clients_freeze_bit_exactly_under_attack(mixing_impl):
    """Participation composes with the adversary slot: inactive clients —
    attackers included — freeze (θ, c) bit-exactly on the linear AND the
    robust epilogues."""
    n, k = 6, 2
    prob, cfg, stt, kb = _byz_setup(n=n, k=k, mixing_impl=mixing_impl,
                                    topology="full")
    step = jax.jit(make_round_step(prob, cfg, participation=True,
                                   byzantine=True))
    fn = make_attack_sampler(n, jax.random.PRNGKey(4), num_byzantine=2,
                             attack="sign_flip", scale=3.0)
    mask = jnp.asarray([True, False, True, False, True, True])
    keys = jax.random.split(jax.random.PRNGKey(9), k * n).reshape(k, n, 2)
    out = step(stt, kb, keys, mask, fn(jnp.int32(0)))
    inactive = ~np.asarray(mask)
    for name in ("x", "y", "cx", "cy"):
        np.testing.assert_array_equal(
            np.asarray(getattr(out, name))[inactive],
            np.asarray(getattr(stt, name))[inactive], err_msg=name)


@pytest.mark.parametrize("mixing_impl", ["dense", "trimmed_mean"])
def test_honest_adversary_extra_matches_plain_step(mixing_impl):
    """An all-honest Adversary extra is a bitwise no-op — the byzantine=True
    program with ids ≡ 0 equals the plain program, on the linear and the
    robust epilogue alike."""
    n, k = 4, 2
    prob, cfg, stt, kb = _byz_setup(n=n, k=k, mixing_impl=mixing_impl,
                                    topology="full")
    keys = jax.random.split(jax.random.PRNGKey(1), k * n).reshape(k, n, 2)
    plain = jax.jit(make_round_step(prob, cfg))(stt, kb, keys)
    honest = jax.jit(make_round_step(prob, cfg, byzantine=True))(
        stt, kb, keys, _adv([0] * n, scale=5.0))
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(honest)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_with_topology_extras_order_and_nesting_guard():
    """Sampler extras arrive as (W, mask, adversary) — the exact operand
    order of make_round_step — and nesting wrappers raises instead of
    silently dropping the inner draws."""
    from repro.engine import sampler as sampler_lib

    n = 4
    base = lambda r: ("batches", "keys")
    w_fn = stoch.make_w_sampler("erdos_renyi", n, jax.random.PRNGKey(0),
                                edge_prob=0.6)
    mask_fn = stoch.make_participation_sampler(n, jax.random.PRNGKey(1), 0.8)
    attack_fn = make_attack_sampler(n, jax.random.PRNGKey(2),
                                    num_byzantine=1, attack="sign_flip")
    wrapped = sampler_lib.with_topology(base, w_fn=w_fn, mask_fn=mask_fn,
                                        attack_fn=attack_fn)
    _, _, extras = wrapped(jnp.int32(3))
    assert len(extras) == 3
    assert extras[0].shape == (n, n)
    assert extras[1].shape == (n,) and extras[1].dtype == bool
    assert isinstance(extras[2], Adversary)
    # mask-only and attack-only wrappers keep relative order
    _, _, extras = sampler_lib.with_topology(
        base, attack_fn=attack_fn)(jnp.int32(0))
    assert len(extras) == 1 and isinstance(extras[0], Adversary)
    with pytest.raises(ValueError, match="needs w_fn"):
        sampler_lib.with_topology(base)
    with pytest.raises(ValueError, match="nesting"):
        sampler_lib.with_topology(wrapped, mask_fn=mask_fn)(jnp.int32(0))


# ---------------------------------------------------------------------------
# the headline: divergence witness
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sign_flip_kills_plain_gossip_but_not_trimmed_mean():
    """f=1 sign-flip attacker at n=8 (the bench_adversary setting): plain
    dense gossip blows up while the trimmed-mean lowering still drives
    ‖∇Φ‖ under the sweep's ε = 0.25."""
    n, k = 8, 4
    res = {}
    for impl in ("dense", "trimmed_mean"):
        key = jax.random.PRNGKey(0)
        data = make_quadratic_data(key, n, dx=10, dy=5, heterogeneity=0.0)
        prob = quadratic_problem(data, sigma=0.0)
        cfg = AlgorithmConfig(num_clients=n, local_steps=k, eta_cx=0.01,
                              eta_cy=0.1, eta_sx=0.5, eta_sy=0.5,
                              topology="full", mixing_impl=impl)
        cb = {kk: v for kk, v in data.items() if kk != "mu"}
        kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)),
                          cb)
        stt = init_state(prob, cfg, key, init_batch=cb,
                         init_keys=jax.random.split(key, n))
        step = jax.jit(make_round_step(prob, cfg, byzantine=True))
        fn = make_attack_sampler(n, jax.random.PRNGKey(3), num_byzantine=1,
                                 attack="sign_flip", scale=3.0)
        rounds = 150 if impl == "dense" else 900
        grad = np.inf
        for t in range(rounds):
            keys = jax.random.split(jax.random.PRNGKey(t),
                                    k * n).reshape(k, n, 2)
            stt = step(stt, kb, keys, fn(jnp.int32(t)))
            if impl == "trimmed_mean" and (t + 1) % 50 == 0:
                grad = float(diagnostics(prob, stt)["phi_grad_norm"])
                if grad < 0.25:
                    break
        res[impl] = (grad if impl == "trimmed_mean"
                     else float(diagnostics(prob, stt)["phi_grad_norm"]))
    assert res["trimmed_mean"] < 0.25
    assert not np.isfinite(res["dense"]) or res["dense"] > 10.0


# ---------------------------------------------------------------------------
# dense-vs-sparse Erdős–Rényi draw parity (the churn-bench correctness fix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("edge_prob", [0.2, 0.5, 0.8])
def test_erdos_renyi_dense_matches_sparse_on_full_support(edge_prob):
    """The dense ER sampler draws one canonical uniform per undirected edge
    on the sparse sampler's convention (slot j−1 of row i for j > i), so the
    same key realizes the *identical edge set* on both paths; the MH
    off-diagonal weights are bit-equal and the diagonal leftover mass agrees
    to summation-order rounding."""
    n = 10
    key = jax.random.PRNGKey(11)
    dense_fn = stoch.make_w_sampler("erdos_renyi", n, key,
                                    edge_prob=edge_prob)
    support = sparse_lib.from_dense(np.asarray(mixing_matrix("full", n)))
    sparse_fn = sparse_lib.make_sparse_w_sampler("erdos_renyi", support, key,
                                                 edge_prob=edge_prob)
    off = ~np.eye(n, dtype=bool)
    for r in (0, 7, 123):
        wd = np.asarray(dense_fn(jnp.int32(r)))
        ws = np.asarray(sparse_lib.densify(sparse_fn(jnp.int32(r))))
        np.testing.assert_array_equal(wd[off] > 0, ws[off] > 0)
        np.testing.assert_array_equal(wd[off], ws[off])
        np.testing.assert_allclose(np.diag(wd), np.diag(ws), atol=1e-6)


def test_erdos_renyi_edge_draw_is_symmetric():
    """Edge {i, j} reads exactly one uniform: the realized adjacency (and
    hence W) is symmetric draw-by-draw, not just in distribution."""
    n = 9
    fn = stoch.make_w_sampler("erdos_renyi", n, jax.random.PRNGKey(5),
                              edge_prob=0.5)
    for r in range(4):
        w = np.asarray(fn(jnp.int32(r)))
        np.testing.assert_array_equal(w, w.T)


# ---------------------------------------------------------------------------
# sweep spec wiring
# ---------------------------------------------------------------------------

def test_adversary_sweep_partition():
    """The adversary grid splits into (3 impls × byz on/off) cells; the
    honest regime dedups its attack axis to one baseline per (impl, seed)."""
    from repro.sweep import defs
    from repro.sweep import run as sweep_run

    spec = defs.SWEEPS["adversary"]
    pts = spec.points()
    assert len(pts) == 3 * 3 * 2 + 3 * 2        # attacked + honest-dedup
    cells = spec.cells()
    assert len(cells) == 6
    for cell in cells:
        full = [sweep_run._full_point(p) for p in cell.points]
        assert len({sweep_run._byz(p) for p in full}) == 1
        for k in sweep_run.STATIC_KEYS:
            assert len({p[k] for p in full}) == 1, (cell.key, k)
    honest = [p for p in pts if p["num_byzantine"] == 0]
    assert {p["attack"] for p in honest} == {"honest"}
