"""Per-assigned-architecture smoke tests (the brief's deliverable f): a
REDUCED same-family variant runs one forward and one K-GT-Minimax train step
on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import AlgorithmConfig, MinimaxConfig
from repro.configs.registry import ASSIGNED, get_model_config, reduced
from repro.core import init_state, make_round_step, objectives
from repro.data import make_data_model, round_batches
from repro.models import forward, init_params, per_group_loss

B, S, G = 2, 32, 4


def _batch(cfg, key):
    if cfg.num_codebooks:
        toks = jax.random.randint(key, (B, S, cfg.num_codebooks), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks,
             "groups": jax.random.randint(key, (B, S), 0, G)}
    if cfg.num_prefix_tokens:
        batch["prefix"] = 0.02 * jax.random.normal(
            key, (B, cfg.num_prefix_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = reduced(get_model_config(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, _, aux = forward(params, batch, cfg)
    if cfg.num_codebooks:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))
    losses, _ = per_group_loss(params, batch, cfg, num_groups=G)
    assert losses.shape == (G,)
    assert bool(jnp.isfinite(losses).all())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_kgt_train_step(arch):
    """One full communication round (K=2 local DRO-minimax steps + tracking +
    gossip) on the reduced variant — no NaNs, consensus finite."""
    cfg = reduced(get_model_config(arch))
    n, K = 2, 2
    algo = AlgorithmConfig(num_clients=n, local_steps=K, eta_cx=1e-3,
                           eta_cy=1e-2, topology="ring")
    problem = objectives.dro_problem(cfg, num_groups=G, mu=1.0)
    key = jax.random.PRNGKey(2)
    dm = make_data_model(key, vocab_size=cfg.vocab_size, num_groups=G,
                         num_clients=n, alpha=0.5)
    batches = round_batches(dm, key, local_steps=K, num_clients=n,
                            per_client_batch=B, seq_len=S, cfg=cfg)
    init_b = jax.tree.map(lambda x: x[0], batches)
    state = init_state(problem, algo, key, init_batch=init_b,
                       init_keys=jax.random.split(key, n))
    step = make_round_step(problem, algo)
    keys = jax.random.split(key, K * n).reshape(K, n, 2)
    new_state = step(state, batches, keys)
    for leaf in jax.tree.leaves(new_state.x):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())
    assert int(new_state.round) == 1
    # parameters actually moved
    moved = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(new_state.x), jax.tree.leaves(state.x)))
    assert moved > 0
