"""Tests of the persistent compile cache (``repro.sweep.cache``).

The load-bearing claims, in order of how expensive they'd be to lose:

* **Warm is bit-identical to cold.**  A fresh-cache run and a
  disk-served rerun of the same point/cell produce identical hits, finals,
  and histories — for the sequential path, the batched path, and the
  batch-bucket-padded batched path (padding rows ride the vmapped scan but
  must never perturb real rows).
* **Stale and corrupt entries recompile, loudly.**  A code-hash change
  rotates every key; garbage bytes under a valid key are detected,
  reported on stderr, deleted, and recompiled — never silently executed.
* **Keys don't collide across statics.**  Every parameter that changes the
  traced program must change ``program_key`` — a collision would silently
  run the wrong executable (the per-entry key-material check is the second
  line of defense, also covered here).
"""
import os
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import engine as engine_lib
from repro.sweep import cache as cache_lib
from repro.sweep import grid
from repro.sweep import run as sweep_run

POINT = dict(n=4, K=2, sigma=0.5, max_rounds=20, eval_every=10, eps=0.0)


def _cache(tmp_path, **kw):
    return cache_lib.CompileCache(str(tmp_path / "aot"), **kw)


# ---------------------------------------------------------------------------
# buckets
# ---------------------------------------------------------------------------

def test_bucket_batch():
    assert [cache_lib.bucket_batch(b) for b in (1, 2, 3, 5, 8)] == \
        [1, 2, 4, 8, 8]
    assert cache_lib.bucket_batch(9) == 16 or cache_lib.bucket_batch(9) % 8 == 0
    assert cache_lib.bucket_batch(17) == 24  # multiples of 8 past 8


def test_length_schedule():
    assert cache_lib.length_schedule(10) == (8, 2)
    assert cache_lib.length_schedule(8) == (8,)
    assert cache_lib.length_schedule(13) == (8, 4, 1)
    assert cache_lib.length_schedule(0) == ()
    for n in range(1, 40):
        assert sum(cache_lib.length_schedule(n)) == n


# ---------------------------------------------------------------------------
# warm == cold, bit for bit
# ---------------------------------------------------------------------------

def test_run_point_warm_bit_identical(tmp_path):
    base = sweep_run.run_point(POINT, cache=None)
    cold_cache = _cache(tmp_path)
    cold = sweep_run.run_point(POINT, cache=cold_cache)
    assert cold_cache.stats["misses"] > 0 and cold_cache.stats["puts"] > 0
    # a fresh CompileCache on the same root simulates a new process: every
    # executable must come from disk
    warm_cache = _cache(tmp_path)
    warm = sweep_run.run_point(POINT, cache=warm_cache)
    assert warm_cache.stats["hits"] > 0
    assert warm_cache.stats["misses"] == 0
    assert warm_cache.stats["errors"] == 0
    for a, b in ((base, cold), (cold, warm)):
        assert a[0] == b[0]          # rounds_to_eps
        assert a[1] == b[1]          # final grad, exact float equality
        assert a[3] == b[3]          # full history


def test_run_cell_warm_and_padded_bit_identical(tmp_path):
    # B=3 pads to the 4-bucket under the cache: the padded program must
    # reproduce the unpadded cache-off results bit for bit
    spec = grid.GridSpec(name="t", base=dict(POINT, eps=0.35, sigma=0.0),
                         axes=(grid.batch_axis("heterogeneity",
                                               0.0, 1.0, 3.0),))
    [cell] = spec.cells()
    base_results, _ = sweep_run.run_cell(cell, cache=None)
    cold_cache = _cache(tmp_path)
    cold_results, _ = sweep_run.run_cell(cell, cache=cold_cache)
    assert base_results == cold_results
    warm_cache = _cache(tmp_path)
    warm_results, _ = sweep_run.run_cell(cell, cache=warm_cache)
    assert warm_cache.stats["misses"] == 0
    assert warm_cache.stats["hits"] > 0
    assert warm_results == cold_results
    # the final trajectories slice back to the real batch
    (_, _), trajs = sweep_run.run_cell(cell, cache=_cache(tmp_path),
                                       return_trajs=True)
    assert trajs.state.x.shape[0] == len(cell.points)


def test_pad_trajectories_freezes_padding():
    p = sweep_run._full_point(dict(POINT, n=4))
    traj, _ = sweep_run.prepare_trajectory(p)
    from repro.sweep import batched as batched_lib

    stacked = batched_lib.tree_stack([traj, traj])
    padded = cache_lib.pad_trajectories(stacked, 2)
    assert padded.state.x.shape[0] == 4
    assert padded.active.tolist() == [True, True, False, False]


# ---------------------------------------------------------------------------
# invalidation: stale code, corrupt entries
# ---------------------------------------------------------------------------

def test_stale_code_hash_forces_recompile(tmp_path, monkeypatch):
    cold = _cache(tmp_path)
    sweep_run.run_point(POINT, cache=cold)
    assert cold.stats["puts"] > 0
    monkeypatch.setitem(cache_lib._CODE_HASH, "hash", "deadbeef00000000")
    stale = _cache(tmp_path)
    sweep_run.run_point(POINT, cache=stale)
    # every lookup must miss: the old entries keyed the old sources
    assert stale.stats["hits"] == 0
    assert stale.stats["misses"] > 0


def test_corrupt_entry_recovers_loudly(tmp_path, capsys):
    cold = _cache(tmp_path)
    expected = sweep_run.run_point(POINT, cache=cold)
    root = tmp_path / "aot"
    entries = sorted(root.glob("*.aotc"))
    assert entries
    for entry in entries:
        entry.write_bytes(b"not a cache entry")
    warm = _cache(tmp_path)
    got = sweep_run.run_point(POINT, cache=warm)
    err = capsys.readouterr().err
    assert "[compile-cache]" in err and "corrupt" in err
    assert warm.stats["errors"] == len(entries)
    assert warm.stats["hits"] == 0 and warm.stats["misses"] > 0
    # corrupt files were deleted and rewritten with good entries
    assert warm.stats["puts"] == len(entries)
    assert got[1] == expected[1] and got[3] == expected[3]


def test_key_material_mismatch_is_loud(tmp_path, capsys):
    # hash collisions / key-construction bugs: an entry whose embedded
    # material disagrees with the lookup's must be rejected, not executed
    cache = _cache(tmp_path)
    fn = jax.jit(lambda x: x + 1)
    args = (jnp.ones((4,)),)
    compiled, info = cache.get_or_compile("t", ("a",), fn, args)
    assert info["source"] == "compile"
    key = cache_lib.program_key("t", ("a",), args)
    other = cache_lib.key_material("t", ("b",), args)
    assert cache.load(key, other) is None
    assert "mismatch" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# key hygiene: distinct statics -> distinct keys
# ---------------------------------------------------------------------------

def test_program_key_separates_statics():
    args = (jnp.ones((4, 10)),)
    variants = [
        ("chunk", (("n", 8), ("algorithm", "kgt_minimax"))),
        ("chunk", (("n", 16), ("algorithm", "kgt_minimax"))),
        ("chunk", (("n", 8), ("algorithm", "local_sgda"))),
        ("preparer", (("n", 8), ("algorithm", "kgt_minimax"))),
        ("phi_eval", (("n", 8), ("algorithm", "kgt_minimax"))),
    ]
    keys = {cache_lib.program_key(kind, statics, args)
            for kind, statics in variants}
    assert len(keys) == len(variants)
    # avals key too: same statics, different shapes
    assert cache_lib.program_key("chunk", variants[0][1],
                                 (jnp.ones((8, 10)),)) not in keys


def test_program_statics_cover_cell_parameters():
    """_program_statics must differ whenever a parameter that changes the
    traced cell program differs — the key-collision regression net for the
    sweep path (and ``_PREPARERS``' key is a subset of these)."""
    base = sweep_run._full_point(dict(POINT))
    seen = {sweep_run._program_statics(base, batched=False)}
    for delta in (dict(n=8), dict(K=4), dict(algorithm="local_sgda"),
                  dict(topology="full"), dict(mixing_impl="gather"),
                  dict(sigma=0.0), dict(topology_family="erdos_renyi"),
                  dict(participation=0.5), dict(num_byzantine=1),
                  dict(gossip_compress="int8"), dict(robust_trim=2)):
        statics = sweep_run._program_statics(
            sweep_run._full_point(dict(POINT, **delta)), batched=False)
        assert statics not in seen, delta
        seen.add(statics)
    # batched vs sequential never share an executable
    assert sweep_run._program_statics(base, batched=True) not in seen
    # ...but eps / round budgets deliberately DO share one
    assert sweep_run._program_statics(
        sweep_run._full_point(dict(POINT, eps=0.1, max_rounds=100)),
        batched=False) in seen


def test_chunk_lengths_key_separately(tmp_path):
    """timed_chunk_builder folds the scan length into the cache key: two
    lengths of the same cell must be two entries, not one collision."""
    cache = _cache(tmp_path)

    def fake_build(length):
        return jax.jit(lambda s, f: (s + length, None))

    build = engine_lib.timed_chunk_builder(fake_build, cache=cache,
                                           statics=(("cell", "t"),))
    s = jnp.float32(0.0)
    s, _ = build(2)(s, jnp.int32(0))
    s, _ = build(3)(s, jnp.int32(0))
    assert float(s) == 5.0
    assert cache.stats["misses"] == 2 and cache.stats["puts"] == 2
    # a fresh cache on the same root serves both lengths from disk and
    # executes the right program for each
    cache2 = _cache(tmp_path)
    build2 = engine_lib.timed_chunk_builder(fake_build, cache=cache2,
                                            statics=(("cell", "t"),))
    s2 = jnp.float32(0.0)
    s2, _ = build2(2)(s2, jnp.int32(0))
    s2, _ = build2(3)(s2, jnp.int32(0))
    assert float(s2) == 5.0
    assert cache2.stats["hits"] == 2 and cache2.stats["misses"] == 0


# ---------------------------------------------------------------------------
# satellites: _timed_eval fallback, timing discipline, clock hygiene
# ---------------------------------------------------------------------------

def test_timed_eval_fallback_is_loud_and_uncharged(capsys):
    class BrokenJit:
        """Quacks like jax.jit but cannot AOT-compile."""

        def lower(self, *args):
            raise RuntimeError("no lowering for you")

        def __call__(self, x):
            return x + 1

    counters = []

    class Tel:
        def counter(self, name, value, **attrs):
            counters.append((name, value, attrs))

    call = sweep_run._timed_eval(BrokenJit(), telemetry=Tel())
    assert int(call(jnp.int32(1))) == 2
    # the failed attempt is NOT charged to compile_s...
    assert call.stats["compile_s"] == 0.0
    # ...and the fallback is loud on both channels
    assert "falling back to on-demand jit" in capsys.readouterr().err
    assert counters and counters[0][0] == "eval_aot_fallback"


def test_run_point_timing_rounded_and_clamped(tmp_path):
    # a fully-warm cached run is the regression trigger: compile_s + setup_s
    # routinely round to within a ms of wall_s, which drove run_s negative
    cache = _cache(tmp_path)
    sweep_run.run_point(POINT, cache=cache)
    _, _, timing, _ = sweep_run.run_point(POINT, cache=cache)
    assert timing["run_s"] >= 0.0
    for key, value in timing.items():
        assert value == round(value, 3), (key, value)


def test_no_wall_clock_stamps_in_timing_paths():
    """The PR-7 eviction of time.time() from timing code, held for the
    modules this PR fixed (engine, sweep runner, launch drivers)."""
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src", "repro")
    for rel in ("engine/engine.py", "sweep/run.py", "sweep/cache.py",
                "launch/train.py", "launch/dryrun.py"):
        with open(os.path.join(src, rel)) as f:
            assert "time.time()" not in f.read(), rel


# ---------------------------------------------------------------------------
# env plumbing
# ---------------------------------------------------------------------------

def test_resolve_env_off_values(monkeypatch):
    for off in ("", "0", "off", "none"):
        monkeypatch.setenv(cache_lib.ENV_CACHE, off)
        assert cache_lib.from_env() is None
    monkeypatch.delenv(cache_lib.ENV_CACHE)
    assert cache_lib.from_env() is None  # unset: no default-on ambush
    assert cache_lib.resolve(None) is None


def test_resolve_env_path(monkeypatch, tmp_path):
    monkeypatch.setenv(cache_lib.ENV_CACHE, str(tmp_path / "c"))
    cache = cache_lib.resolve(cache_lib.UNSET)
    assert cache is not None
    assert cache.root == str(tmp_path / "c" / "aot")
    # memoized per env value: run_point calls share one executable memo
    assert cache_lib.resolve(cache_lib.UNSET) is cache
