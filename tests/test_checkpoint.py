import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest, load_metadata, restore, save
from repro.core.kgt_minimax import KGTState


def _state():
    return KGTState(
        x={"w": jnp.arange(6.0).reshape(2, 3)},
        y=jnp.ones((2, 4)),
        cx={"w": jnp.zeros((2, 3))},
        cy=jnp.zeros((2, 4)),
        round=jnp.int32(7),
    )


def test_roundtrip(tmp_path):
    st = _state()
    path = str(tmp_path / "ck.npz")
    save(path, st, metadata={"round": 7})
    template = jax.tree.map(jnp.zeros_like, st)
    back = restore(path, template)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert load_metadata(path)["round"] == 7


def test_shape_mismatch_raises(tmp_path):
    st = _state()
    path = str(tmp_path / "ck.npz")
    save(path, st)
    bad = KGTState(x={"w": jnp.zeros((3, 3))}, y=st.y, cx=st.cx, cy=st.cy,
                   round=st.round)
    with pytest.raises(ValueError):
        restore(path, bad)


def test_latest(tmp_path):
    assert latest(str(tmp_path)) is None
    for name in ("round_000001.npz", "round_000010.npz"):
        save(str(tmp_path / name), {"a": jnp.zeros(1)})
    assert latest(str(tmp_path)).endswith("round_000010.npz")
