import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_model_config, reduced
from repro.data import (
    heterogeneity_index,
    make_data_model,
    round_batches,
    sample_client_batch,
)

KEY = jax.random.PRNGKey(0)


def test_batch_shapes_and_ranges():
    dm = make_data_model(KEY, vocab_size=512, num_groups=8, num_clients=4, alpha=0.3)
    b = sample_client_batch(dm, KEY, client=1, batch=3, seq_len=16)
    assert b["tokens"].shape == (3, 16)
    assert b["labels"].shape == (3, 16)
    assert b["groups"].shape == (3, 16)
    assert int(b["tokens"].max()) < 512 and int(b["tokens"].min()) >= 0
    assert int(b["groups"].max()) < 8


def test_codebook_batch():
    dm = make_data_model(KEY, vocab_size=128, num_groups=4, num_clients=2)
    b = sample_client_batch(dm, KEY, client=0, batch=2, seq_len=8, num_codebooks=4)
    assert b["tokens"].shape == (2, 8, 4)
    assert b["labels"].shape == (2, 8, 4)


def test_heterogeneity_monotonic_in_alpha():
    his = []
    for alpha in (0.05, 0.5, 50.0):
        dm = make_data_model(KEY, vocab_size=128, num_groups=8, num_clients=8,
                             alpha=alpha)
        his.append(heterogeneity_index(dm))
    assert his[0] > his[1] > his[2]


def test_round_batches_stacked_shapes():
    cfg = reduced(get_model_config("internvl2-76b"))
    dm = make_data_model(KEY, vocab_size=cfg.vocab_size, num_groups=4,
                         num_clients=3)
    rb = round_batches(dm, KEY, local_steps=2, num_clients=3,
                       per_client_batch=2, seq_len=8, cfg=cfg)
    assert rb["tokens"].shape == (2, 3, 2, 8)
    assert rb["prefix"].shape == (2, 3, 2, cfg.num_prefix_tokens, cfg.d_model)


def test_determinism():
    dm = make_data_model(KEY, vocab_size=64, num_groups=4, num_clients=2)
    a = sample_client_batch(dm, KEY, 0, 2, 8)
    b = sample_client_batch(dm, KEY, 0, 2, 8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_prng_streams_are_independent():
    """Regression for the PR-3 key-reuse fix.  Pre-fix, ``make_data_model``
    drew the Dirichlet mixtures from the same key as the vocab-tile noise and
    ``sample_client_batch`` drew the bigram mask from the domain-draw key —
    coupling streams that must be independent (and shifting every sampled
    trajectory for a given seed when fixed; stats pinned below).  This pins
    the post-fix key-splitting scheme white-box."""
    # make_data_model: mixtures come from the 4th split of the caller key.
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    dm = make_data_model(KEY, vocab_size=8192, num_groups=4, num_clients=3,
                         alpha=0.3)
    expect_mix = jax.random.dirichlet(k4, jnp.full((4,), 0.3), (3,))
    np.testing.assert_array_equal(np.asarray(dm.mixtures),
                                  np.asarray(expect_mix))
    assert not np.array_equal(
        np.asarray(dm.mixtures),
        np.asarray(jax.random.dirichlet(k3, jnp.full((4,), 0.3), (3,))))

    # sample_client_batch: bigram mask comes from the 3rd split, domain draw
    # from the 1st — reusing kg for the mask must stay gone.
    dm = make_data_model(KEY, vocab_size=64, num_groups=4, num_clients=2)
    kg, kt, kb = jax.random.split(KEY, 3)
    b = sample_client_batch(dm, KEY, 0, 4, 16)
    g = jax.random.categorical(kg, jnp.log(dm.mixtures[0] + 1e-9), shape=(4,))
    np.testing.assert_array_equal(np.asarray(b["groups"][:, 0]), np.asarray(g))
    mask = jax.random.bernoulli(kb, 0.5, (4, 17))
    first = jax.random.categorical(kt, dm.domain_logits[g], shape=(17, 4)).T
    prev = jnp.roll(first, 1, axis=1).at[:, 0].set(first[:, 0])
    seq = jnp.where(mask, (prev + dm.domain_shift[g][:, None]) % 64, first)
    np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                  np.asarray(seq[:, :-1]))


def test_seeded_stats_pinned_after_rng_fix():
    """Expected stat shift from the key-reuse fix, pinned for seed 0: these
    values differ from the pre-fix stream (the mask/mixtures changed)."""
    dm = make_data_model(KEY, vocab_size=64, num_groups=4, num_clients=2,
                         alpha=0.3)
    b = sample_client_batch(dm, KEY, 0, 32, 32)
    # mean token id is seed-deterministic; loose enough to survive platform
    # quirks, tight enough to catch a stream change.
    mean_tok = float(np.asarray(b["tokens"], np.float64).mean())
    assert abs(mean_tok - 32.0) < 12.0
    a = sample_client_batch(dm, jax.random.PRNGKey(1), 0, 32, 32)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_sampling_is_jittable_with_traced_inputs():
    """The engine samples inside ``lax.scan`` — key and client must be
    traceable (no host-side control flow on data)."""
    dm = make_data_model(KEY, vocab_size=64, num_groups=4, num_clients=3)

    @jax.jit
    def sample(round_idx, client):
        k = jax.random.fold_in(KEY, round_idx)
        return sample_client_batch(dm, k, client, 2, 8)

    a = sample(jnp.int32(3), jnp.int32(1))
    k = jax.random.fold_in(KEY, 3)
    b = sample_client_batch(dm, k, 1, 2, 8)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
