import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_model_config, reduced
from repro.data import (
    heterogeneity_index,
    make_data_model,
    round_batches,
    sample_client_batch,
)

KEY = jax.random.PRNGKey(0)


def test_batch_shapes_and_ranges():
    dm = make_data_model(KEY, vocab_size=512, num_groups=8, num_clients=4, alpha=0.3)
    b = sample_client_batch(dm, KEY, client=1, batch=3, seq_len=16)
    assert b["tokens"].shape == (3, 16)
    assert b["labels"].shape == (3, 16)
    assert b["groups"].shape == (3, 16)
    assert int(b["tokens"].max()) < 512 and int(b["tokens"].min()) >= 0
    assert int(b["groups"].max()) < 8


def test_codebook_batch():
    dm = make_data_model(KEY, vocab_size=128, num_groups=4, num_clients=2)
    b = sample_client_batch(dm, KEY, client=0, batch=2, seq_len=8, num_codebooks=4)
    assert b["tokens"].shape == (2, 8, 4)
    assert b["labels"].shape == (2, 8, 4)


def test_heterogeneity_monotonic_in_alpha():
    his = []
    for alpha in (0.05, 0.5, 50.0):
        dm = make_data_model(KEY, vocab_size=128, num_groups=8, num_clients=8,
                             alpha=alpha)
        his.append(heterogeneity_index(dm))
    assert his[0] > his[1] > his[2]


def test_round_batches_stacked_shapes():
    cfg = reduced(get_model_config("internvl2-76b"))
    dm = make_data_model(KEY, vocab_size=cfg.vocab_size, num_groups=4,
                         num_clients=3)
    rb = round_batches(dm, KEY, local_steps=2, num_clients=3,
                       per_client_batch=2, seq_len=8, cfg=cfg)
    assert rb["tokens"].shape == (2, 3, 2, 8)
    assert rb["prefix"].shape == (2, 3, 2, cfg.num_prefix_tokens, cfg.d_model)


def test_determinism():
    dm = make_data_model(KEY, vocab_size=64, num_groups=4, num_clients=2)
    a = sample_client_batch(dm, KEY, 0, 2, 8)
    b = sample_client_batch(dm, KEY, 0, 2, 8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
