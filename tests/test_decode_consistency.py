"""Decode-vs-forward consistency: running the model autoregressively with
caches must reproduce the full-sequence forward logits — per family, covering
attention KV caches, SSM state, RG-LRU state, and ring-buffer windows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_model_config, reduced
from repro.models import decode_step, forward, init_cache, init_params

FAMILIES = ["qwen2-0.5b", "granite-moe-1b-a400m", "mamba2-1.3b",
            "recurrentgemma-9b", "musicgen-medium"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_forward(arch):
    import dataclasses
    cfg = reduced(get_model_config(arch))
    if cfg.arch_type == "moe":
        # capacity-dropping differs between full-seq forward and per-token
        # decode by design; remove drops to compare the pure math
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 1, 16
    if cfg.num_codebooks:
        toks = jax.random.randint(key, (B, S, cfg.num_codebooks), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    # full forward (float32 compute to make comparison tight)
    full_logits, _, _ = forward(params, {"tokens": toks}, cfg,
                                compute_dtype=jnp.float32)

    # token-by-token decode
    caches = init_cache(cfg, B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        tok_t = toks[:, t : t + 1]
        logits_t, caches = decode_step(params, caches, tok_t, jnp.int32(t), cfg,
                                       compute_dtype=jnp.float32)
        outs.append(logits_t)
    dec_logits = jnp.concatenate(outs, axis=1)

    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-3, atol=2e-3)
