"""Tests for the ``repro.dist`` sharding/context subsystem.

Spec-level tests use a device-free AbstractMesh (so they run on the 1-CPU
container); the compile-level check (every step program jit-compiles on a
CPU fake mesh) runs ``repro.launch.smoke`` in a subprocess because the
XLA host-device-count flag must be set before jax's first backend init.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs.registry import get_model_config, reduced
from repro.dist import compat
from repro.dist import context as dist_ctx
from repro.dist import sharding as sh
from repro.models import model as model_lib

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _dec_mesh(clients=4, fsdp=2, model=2):
    return compat.abstract_mesh(
        {sh.CLIENTS: clients, sh.FSDP: fsdp, sh.MODEL: model})


def _stacked_params_sds(arch, n=4):
    """Client-stacked abstract params, like build_train_round's x_sds."""
    cfg = reduced(get_model_config(arch))
    one = jax.eval_shape(lambda k: model_lib.init_params(cfg, k),
                         jax.random.PRNGKey(0))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), one)


def _assert_divisible(sds_tree, shard_tree, mesh):
    sizes = dict(mesh.shape)
    for sds, ns in zip(jax.tree.leaves(sds_tree), jax.tree.leaves(shard_tree)):
        for dim, entry in enumerate(ns.spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            extent = int(np.prod([sizes[a] for a in axes]))
            assert sds.shape[dim] % extent == 0, (sds.shape, ns.spec, dim)


# ---------------------------------------------------------------------------
# params_shardings (decentralized training mesh)
# ---------------------------------------------------------------------------

def test_params_shardings_leading_clients_dim():
    """The core invariant: dim 0 of every state leaf sits on the clients
    axis — per-client compute stays inside a client; only gossip mixes."""
    mesh = _dec_mesh()
    sds = _stacked_params_sds("qwen2-0.5b")
    shards = sh.params_shardings(sds, mesh)
    for ns in jax.tree.leaves(shards):
        spec = ns.spec
        assert spec[0] == sh.CLIENTS
        assert sh.CLIENTS not in spec[1:]
    _assert_divisible(sds, shards, mesh)


def test_params_shardings_fsdp2d_shards_within_client():
    mesh = _dec_mesh()
    sds = _stacked_params_sds("qwen2-0.5b")
    shards = sh.params_shardings(sds, mesh, param_mode="fsdp2d")
    embed = shards["embed"].spec
    assert sh.MODEL in embed[1:] and sh.FSDP in embed[1:]


def test_params_shardings_replicated_mode():
    mesh = _dec_mesh()
    sds = _stacked_params_sds("qwen2-0.5b")
    shards = sh.params_shardings(sds, mesh, param_mode="replicated")
    for ns in jax.tree.leaves(shards):
        assert ns.spec[0] == sh.CLIENTS
        assert all(p is None for p in ns.spec[1:])


def test_params_shardings_expert_parallel_pins_expert_dim():
    mesh = _dec_mesh()
    sds = _stacked_params_sds("granite-moe-1b-a400m")
    shards = sh.params_shardings(sds, mesh, expert_parallel=True)
    seen = 0
    for path, ns in jax.tree_util.tree_leaves_with_path(shards):
        keys = [getattr(p, "key", None) for p in path]
        if "moe" in keys and keys[-1] in ("gate", "up", "down"):
            assert ns.spec[len(ns.spec) - 3] == sh.MODEL, (keys, ns.spec)
            seen += 1
    assert seen >= 3  # gate/up/down present


def test_params_shardings_never_shards_indivisible_dims():
    mesh = _dec_mesh(clients=4, fsdp=2, model=2)
    tree = {"w": jax.ShapeDtypeStruct((4, 7, 5), jnp.float32)}
    shards = sh.params_shardings(tree, mesh)
    assert shards["w"].spec[0] == sh.CLIENTS
    assert all(p is None for p in shards["w"].spec[1:])


# ---------------------------------------------------------------------------
# serve_params_shardings (production mesh)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("axes", [{"data": 4, "model": 2},
                                  {"pod": 2, "data": 2, "model": 2}])
def test_serve_params_shardings_tp_over_model_only(axes):
    mesh = compat.abstract_mesh(axes)
    cfg = reduced(get_model_config("qwen2-0.5b"))
    sds = jax.eval_shape(lambda k: model_lib.init_params(cfg, k),
                         jax.random.PRNGKey(0))
    shards = sh.serve_params_shardings(sds, mesh)
    _assert_divisible(sds, shards, mesh)
    model_hits = 0
    for ns in jax.tree.leaves(shards):
        for entry in ns.spec:
            assert entry in (None, "model"), ns.spec  # replicated over batch axes
            model_hits += entry == "model"
    assert model_hits > 0
    assert "model" in shards["embed"].spec


def test_serve_params_shardings_expert_parallel():
    mesh = compat.abstract_mesh({"data": 4, "model": 2})
    cfg = reduced(get_model_config("granite-moe-1b-a400m"))
    sds = jax.eval_shape(lambda k: model_lib.init_params(cfg, k),
                         jax.random.PRNGKey(0))
    shards = sh.serve_params_shardings(sds, mesh, expert_parallel=True)
    for path, ns in jax.tree_util.tree_leaves_with_path(shards):
        keys = [getattr(p, "key", None) for p in path]
        if "moe" in keys and keys[-1] in ("gate", "up", "down"):
            assert ns.spec[len(ns.spec) - 3] == "model"


# ---------------------------------------------------------------------------
# context
# ---------------------------------------------------------------------------

def test_apply_is_identity_without_context():
    x = jnp.ones((2, 3))
    assert dist_ctx.apply("attn_qkv", x) is x
    assert dist_ctx.apply_residual(x) is x
    assert dist_ctx.current_slots() == {}


def test_residual_constraint_installs_and_restores():
    calls = []

    def fn(x):
        calls.append(x.shape)
        return x

    x = jnp.ones((2, 3))
    with dist_ctx.residual_constraint(fn):
        assert dist_ctx.apply_residual(x) is x
    assert calls == [(2, 3)]
    dist_ctx.apply_residual(x)
    assert calls == [(2, 3)]  # popped on exit


def test_tagged_slots_and_nesting_shadowing():
    order = []
    outer = {"attn_qkv": lambda x: order.append("outer_qkv") or x,
             "attn_out": lambda x: order.append("outer_out") or x}
    inner_qkv = lambda x: order.append("inner_qkv") or x
    x = jnp.zeros(())
    with dist_ctx.residual_constraint(**outer):
        with dist_ctx.residual_constraint(attn_qkv=inner_qkv):
            dist_ctx.apply("attn_qkv", x)   # inner shadows outer
            dist_ctx.apply("attn_out", x)   # falls through to outer
        dist_ctx.apply("attn_qkv", x)       # back to outer
    assert order == ["inner_qkv", "outer_out", "outer_qkv"]


def test_residual_axes_modes():
    assert sh.residual_axes("batch") == (sh.FSDP,)
    assert sh.residual_axes("batch_seq") == (sh.FSDP, sh.MODEL)
    with pytest.raises(ValueError):
        sh.residual_axes("bogus")


@pytest.mark.parametrize("mode", ["batch", "batch_seq"])
def test_residual_constraint_roundtrips_apply_residual(mode):
    """Under jit, the installed residual constraint must be value-preserving
    in both MeshConfig.residual_mode settings (it only pins layout)."""
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                (sh.CLIENTS, sh.FSDP, sh.MODEL))
    fn = sh.leading_dims_constraint(mesh, sh.residual_axes(mode))
    x = jnp.arange(24, dtype=jnp.float32).reshape(2, 3, 4)
    jitted = jax.jit(lambda v: dist_ctx.apply_residual(v) * 1.0)
    with dist_ctx.residual_constraint(fn):
        out = jitted(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    # 1-D arrays (fewer dims than the axes tuple) pass through untouched
    v = jnp.arange(3.0)
    with dist_ctx.residual_constraint(fn):
        np.testing.assert_array_equal(np.asarray(fn(v)), np.asarray(v))


# ---------------------------------------------------------------------------
# compile-level smoke (subprocess: XLA flag must precede jax init)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_steps_compile_on_cpu_fake_mesh():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.smoke"],
        env=env, capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    assert "train round compiled" in proc.stdout
    assert "packed-gossip train round compiled" in proc.stdout
    assert "sparse-gossip train round compiled" in proc.stdout
    assert "sweep cell" in proc.stdout and "compiled" in proc.stdout
    assert "prefill+decode compiled" in proc.stdout
