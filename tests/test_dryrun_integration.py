"""Integration test of the multi-pod dry-run machinery (subprocess: the
XLA host-device-count flag must be set before jax init)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_single_pair(tmp_path):
    out = tmp_path / "dry.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--archs", "qwen2-0.5b", "--shapes", "decode_32k",
         "--meshes", "single", "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-2000:]
    recs = [json.loads(l) for l in open(out)]
    assert len(recs) == 1
    r = recs[0]
    assert "error" not in r, r.get("error")
    assert r["mesh_shape"] == {"data": 16, "model": 16}
    assert r["memory"]["peak_per_device"] > 0
    assert r["cost"]["dot_flops"] > 0
    assert r["compile_s"] > 0
