"""Tests of the chunked scan-over-rounds execution engine (repro.engine).

The load-bearing claim: the engine is an *execution model* change only —
scanned chunks with device-side sampling produce the bit-identical
trajectory to the historical per-round host loop, for every algorithm
variant, mixing lowering, and the time-varying topology path; the streaming
metrics buffer matches host-computed diagnostics; and checkpoint-restore
mid-run resumes the identical trajectory.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as engine_lib
from repro.configs.base import AlgorithmConfig
from repro.core import (
    init_state,
    make_quadratic_data,
    make_round_step,
    mixing_matrix,
    quadratic_problem,
)
from repro.core import stochastic_topology as stoch

ALGOS = ["kgt_minimax", "dsgda", "local_sgda", "gt_gda"]


def _setup(algo="kgt_minimax", mixing_impl="dense", topology="ring",
           topology_cycle=(), n=4, K=3, sigma=0.3, seed=0):
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=6, dy=3, heterogeneity=1.5)
    prob = quadratic_problem(data, sigma=sigma)
    cfg = AlgorithmConfig(
        algorithm=algo, num_clients=n, local_steps=K, eta_cx=0.01,
        eta_cy=0.1, eta_sx=0.5, eta_sy=0.5, topology=topology,
        mixing_impl=mixing_impl, topology_cycle=topology_cycle,
        gossip_backend="xla")
    cb = {k: v for k, v in data.items() if k != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), cb)
    st = init_state(prob, cfg, key, init_batch=cb,
                    init_keys=jax.random.split(key, n))
    step = make_round_step(prob, cfg)
    sampler = engine_lib.make_fixed_batch_sampler(
        kb, local_steps=K, num_clients=n, seed=seed)
    return prob, st, step, sampler


def _churn_setup(family="erdos_renyi", rate=0.7, mixing_impl="dense",
                 n=4, K=3, sigma=0.3, seed=0, byz=0, attack="sign_flip"):
    """_setup plus the churn/adversary axes: a per-round sampled W (and
    participation mask when rate < 1, and Byzantine adversary when byz > 0)
    riding the sampler slot via with_topology, and a round_step taking them
    as traced operands."""
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=6, dy=3, heterogeneity=1.5)
    prob = quadratic_problem(data, sigma=sigma)
    cfg = AlgorithmConfig(
        algorithm="kgt_minimax", num_clients=n, local_steps=K, eta_cx=0.01,
        eta_cy=0.1, eta_sx=0.5, eta_sy=0.5, topology="full",
        mixing_impl=mixing_impl, gossip_backend="xla")
    cb = {k: v for k, v in data.items() if k != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), cb)
    st = init_state(prob, cfg, key, init_batch=cb,
                    init_keys=jax.random.split(key, n))
    part = rate < 1.0
    step = make_round_step(prob, cfg, traced_w=(family != "static"),
                           participation=part, byzantine=byz > 0)
    base = engine_lib.make_fixed_batch_sampler(
        kb, local_steps=K, num_clients=n, seed=seed)
    tkey = jax.random.PRNGKey(seed * 31 + 7)
    w_fn = None
    if family != "static":
        w_fn = stoch.make_w_sampler(
            family, n, tkey, base_w=mixing_matrix("full", n),
            edge_prob=0.5, client_drop_prob=0.3)
    mask_fn = stoch.make_participation_sampler(n, tkey, rate) if part else None
    attack_fn = None
    if byz:
        from repro.core import adversary as adversary_lib

        attack_fn = adversary_lib.make_attack_sampler(
            n, tkey, num_byzantine=byz, attack=attack, scale=2.0)
    sampler = engine_lib.with_topology(base, w_fn=w_fn, mask_fn=mask_fn,
                                       attack_fn=attack_fn)
    return prob, st, step, sampler


def _host_loop(st, step, sampler, rounds):
    jstep = jax.jit(step)
    for t in range(rounds):
        batches, keys, extras = engine_lib.split_sampled(sampler(jnp.int32(t)))
        st = jstep(st, batches, keys, *extras)
    return st


def _assert_states_equal(a, b, context=""):
    for name in ("x", "y", "cx", "cy"):
        for la, lb in zip(jax.tree.leaves(getattr(a, name)),
                          jax.tree.leaves(getattr(b, name))):
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb), err_msg=f"{context}:{name}")
    assert int(a.round) == int(b.round)


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("mixing_impl", ["dense", "pallas_packed"])
def test_engine_trajectory_bit_identical_to_host_loop(algo, mixing_impl):
    """10 rounds in chunks of 4 (2 full chunks + remainder) == 10 per-round
    dispatches, bit for bit, for every algorithm × lowering."""
    prob, st, step, sampler = _setup(algo=algo, mixing_impl=mixing_impl)
    build = engine_lib.make_chunk_builder(step, sampler, donate=False)
    st_engine, _ = engine_lib.run(st, build, total_rounds=10, chunk_rounds=4)
    st_host = _host_loop(st, step, sampler, 10)
    _assert_states_equal(st_engine, st_host, f"{algo}/{mixing_impl}")


@pytest.mark.parametrize("mixing_impl", ["dense", "pallas_packed"])
def test_engine_matches_host_loop_topology_cycle(mixing_impl):
    """The time-varying gossip path (W selected per round from the cycle by
    state.round) must keep round indexing straight inside the scan."""
    prob, st, step, sampler = _setup(
        algo="kgt_minimax", mixing_impl=mixing_impl,
        topology_cycle=("ring", "full", "exp"))
    build = engine_lib.make_chunk_builder(step, sampler, donate=False)
    st_engine, _ = engine_lib.run(st, build, total_rounds=7, chunk_rounds=4)
    st_host = _host_loop(st, step, sampler, 7)
    _assert_states_equal(st_engine, st_host, f"cycle/{mixing_impl}")


@pytest.mark.parametrize("family,rate,mixing_impl", [
    ("erdos_renyi", 0.7, "dense"),
    ("pairwise", 1.0, "dense"),
    ("dropout", 0.6, "pallas_packed"),
])
def test_engine_matches_host_loop_stochastic_topology(family, rate,
                                                      mixing_impl):
    """Churn on the sampler slot: per-round sampled W + participation mask
    inside the scanned chunk == the per-round host loop, bit for bit."""
    prob, st, step, sampler = _churn_setup(
        family=family, rate=rate, mixing_impl=mixing_impl)
    build = engine_lib.make_chunk_builder(step, sampler, donate=False)
    st_engine, _ = engine_lib.run(st, build, total_rounds=7, chunk_rounds=3)
    st_host = _host_loop(st, step, sampler, 7)
    _assert_states_equal(st_engine, st_host, f"{family}/{rate}/{mixing_impl}")


@pytest.mark.parametrize("family,rate,byz,attack", [
    ("static", 1.0, 1, "sign_flip"),            # adversary-only extra
    ("erdos_renyi", 0.7, 2, "random_noise"),    # all three extras at once
])
def test_engine_matches_host_loop_byzantine(family, rate, byz, attack):
    """The adversary on the sampler slot: per-round attack draws inside the
    scanned chunk == the per-round host loop, bit for bit — alone and
    stacked with the W and participation extras (order W, mask, adversary)."""
    prob, st, step, sampler = _churn_setup(family=family, rate=rate,
                                           byz=byz, attack=attack)
    build = engine_lib.make_chunk_builder(step, sampler, donate=False)
    st_engine, _ = engine_lib.run(st, build, total_rounds=7, chunk_rounds=3)
    st_host = _host_loop(st, step, sampler, 7)
    _assert_states_equal(st_engine, st_host, f"byz/{family}/{attack}")


def test_wall_clock_stamps_are_millisecond_grained_and_nonnegative():
    """Every history record carries wall_s/compile_s/run_s at 3-decimal
    (millisecond) resolution — 1-decimal rounding used to collapse sub-100ms
    chunks to wall_s = 0.0 — with run_s clamped at ≥ 0 (compile_s is
    measured around the AOT build, wall per run, so tiny first chunks could
    go negative) and wall_s nondecreasing across chunk boundaries."""
    prob, st, step, sampler = _setup()
    build = engine_lib.make_chunk_builder(
        step, sampler, engine_lib.quadratic_metrics_fn(prob),
        log_every=1, donate=False)
    _, history = engine_lib.run(st, build, total_rounds=6, chunk_rounds=2)
    assert len(history) == 6
    prev_wall = 0.0
    for rec in history:
        for stamp in ("wall_s", "compile_s", "run_s"):
            assert rec[stamp] == round(rec[stamp], 3), (stamp, rec)
            assert rec[stamp] >= 0.0, (stamp, rec)
        assert rec["wall_s"] >= prev_wall
        prev_wall = rec["wall_s"]
    # the first run compiles: its elapsed time cannot round to zero
    assert history[-1]["wall_s"] > 0.0


def test_checkpoint_restore_resumes_stochastic_topology(tmp_path):
    """Mid-run restore under a time-varying *random* topology + partial
    participation: the W/mask draws key off state.round (fold_in), so a
    restored checkpoint replays the exact remaining W/mask sequence — with
    misaligned chunk boundaries on the resume leg."""
    from repro.checkpoint import checkpoint as ckpt_lib

    prob, st, step, sampler = _churn_setup(family="erdos_renyi", rate=0.6,
                                           sigma=0.4)
    build = engine_lib.make_chunk_builder(step, sampler, donate=False)
    hook = engine_lib.checkpoint_hook(str(tmp_path), every=4)
    st_full, _ = engine_lib.run(st, build, total_rounds=9, chunk_rounds=2,
                                hooks=[hook])

    ckpt = str(tmp_path / "round_000004.npz")
    assert ckpt_lib.load_metadata(ckpt)["round"] == 4
    template = jax.tree.map(jnp.zeros_like, st)
    st_resumed = ckpt_lib.restore(ckpt, template)
    st_resumed, _ = engine_lib.run(st_resumed, build, total_rounds=9,
                                   chunk_rounds=3)  # misaligned chunks
    _assert_states_equal(st_resumed, st_full, "churn-resume")


def test_metrics_buffer_matches_host_diagnostics():
    """Rows of the on-device metrics buffer == the same metrics computed
    host-side on the per-round trajectory at the same rounds."""
    prob, st, step, sampler = _setup(sigma=0.2)
    metrics_fn = engine_lib.quadratic_metrics_fn(prob)
    build = engine_lib.make_chunk_builder(step, sampler, metrics_fn,
                                          log_every=3, donate=False)
    _, history = engine_lib.run(st, build, total_rounds=8, chunk_rounds=4)
    assert [h["round"] for h in history] == [0, 3, 6, 7]

    jstep = jax.jit(step)
    jmetrics = jax.jit(metrics_fn)
    st_host = st
    by_round = {}
    for t in range(8):
        batches, keys = sampler(jnp.int32(t))
        st_host = jstep(st_host, batches, keys)
        if t in (0, 3, 6, 7):
            by_round[t] = jax.device_get(jmetrics(st_host, batches))
    for rec in history:
        expect = by_round[rec["round"]]
        for name, v in expect.items():
            np.testing.assert_allclose(rec[name], np.asarray(v), rtol=1e-6,
                                       err_msg=f"round {rec['round']}:{name}")


def test_final_round_always_logged():
    prob, st, step, sampler = _setup()
    build = engine_lib.make_chunk_builder(
        step, sampler, engine_lib.quadratic_metrics_fn(prob),
        log_every=100, donate=False)
    _, history = engine_lib.run(st, build, total_rounds=5, chunk_rounds=5)
    assert [h["round"] for h in history] == [0, 4]
    assert all("wall_s" in h for h in history)


def test_checkpoint_restore_resumes_identical_trajectory(tmp_path):
    """Mid-run restore: state.round is the single source of truth for the
    sampler, schedule, and metrics gating, so resuming a round-4 checkpoint
    replays rounds 4..8 bit-identically."""
    from repro.checkpoint import checkpoint as ckpt_lib

    prob, st, step, sampler = _setup(sigma=0.4)
    build = engine_lib.make_chunk_builder(step, sampler, donate=False)

    hook = engine_lib.checkpoint_hook(str(tmp_path), every=4)
    st_full, _ = engine_lib.run(st, build, total_rounds=8, chunk_rounds=2,
                                hooks=[hook])

    ckpt = str(tmp_path / "round_000004.npz")
    assert ckpt_lib.load_metadata(ckpt)["round"] == 4
    template = jax.tree.map(jnp.zeros_like, st)
    st_resumed = ckpt_lib.restore(ckpt, template)
    assert int(st_resumed.round) == 4
    st_resumed, _ = engine_lib.run(st_resumed, build, total_rounds=8,
                                   chunk_rounds=3)  # misaligned chunks too
    _assert_states_equal(st_resumed, st_full, "resume")


def test_checkpoint_hook_fires_on_boundary_crossings(tmp_path):
    prob, st, step, sampler = _setup()
    build = engine_lib.make_chunk_builder(step, sampler, donate=False)
    hook = engine_lib.checkpoint_hook(str(tmp_path), every=5)
    engine_lib.run(st, build, total_rounds=12, chunk_rounds=4, hooks=[hook])
    import os

    names = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    # boundaries at 4, 8, 12: crossings of 5-multiples happen at 8 and 12
    assert names == ["round_000008.npz", "round_000012.npz"]


def test_boundary_every_aligns_checkpoints_to_exact_multiples(tmp_path):
    """run(boundary_every=N) splits chunks so checkpoints land on the exact
    requested multiples even when N is not a multiple of the chunk size."""
    prob, st, step, sampler = _setup()
    build = engine_lib.make_chunk_builder(step, sampler, donate=False)
    hook = engine_lib.checkpoint_hook(str(tmp_path), every=5)
    engine_lib.run(st, build, total_rounds=12, chunk_rounds=4, hooks=[hook],
                   boundary_every=5)
    import os

    names = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert names == ["round_000005.npz", "round_000010.npz"]


def test_stop_fn_exits_at_chunk_boundary():
    prob, st, step, sampler = _setup()
    build = engine_lib.make_chunk_builder(
        step, sampler, engine_lib.quadratic_metrics_fn(prob), log_every=2,
        donate=False)
    seen = []

    def stop(records):
        seen.extend(r["round"] for r in records)
        return True  # stop after the first chunk

    st_out, history = engine_lib.run(st, build, total_rounds=100,
                                     chunk_rounds=4, stop_fn=stop)
    assert int(st_out.round) == 4
    assert seen == [0, 2]


def test_donated_state_chunks_match_undonated():
    """engine.run donates state buffers across chunk calls by default —
    donation must not change the trajectory."""
    prob, st, step, sampler = _setup(sigma=0.1)
    b_don = engine_lib.make_chunk_builder(step, sampler)          # donate
    b_ref = engine_lib.make_chunk_builder(step, sampler, donate=False)
    st_r, _ = engine_lib.run(st, b_ref, total_rounds=6, chunk_rounds=3)
    # donated run second: its first chunk call consumes st's buffers
    st_d, _ = engine_lib.run(st, b_don, total_rounds=6, chunk_rounds=3)
    _assert_states_equal(st_d, st_r, "donation")
