"""Tests for the beyond-deliverable extensions: continuous-batching serving,
dropless sorted MoE dispatch, evaluation metrics, bf16 tracking state, and
time-varying gossip topologies."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AlgorithmConfig
from repro.configs.registry import get_model_config, reduced
from repro.core import (
    diagnostics,
    init_state,
    make_quadratic_data,
    make_round_step,
    quadratic_problem,
)
from repro.data import make_data_model, sample_client_batch
from repro.evaluation import group_metrics
from repro.models import init_params
from repro.models import moe as moe_lib
from repro.serving import Request, ServingEngine


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------

def test_serving_engine_continuous_batching():
    cfg = reduced(get_model_config("qwen2-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, num_slots=2, max_len=48)
    rng = np.random.default_rng(0)
    for uid in range(4):  # 4 requests through 2 slots => recycling
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                           max_new_tokens=3))
    done = eng.run(max_ticks=200)
    assert sorted(done) == [0, 1, 2, 3]
    for r in done.values():
        assert r.output.shape == (3,)
        assert (r.output >= 0).all() and (r.output < cfg.vocab_size).all()


def test_serving_engine_respects_max_len():
    cfg = reduced(get_model_config("mamba2-1.3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, num_slots=1, max_len=12)
    eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=100))
    done = eng.run(max_ticks=50)
    assert 0 in done
    assert len(done[0].output) <= 12  # capped by cache length


# ---------------------------------------------------------------------------
# Sorted (dropless) MoE dispatch
# ---------------------------------------------------------------------------

def test_sorted_dispatch_matches_dense_without_drops():
    cfg = reduced(get_model_config("granite-moe-1b-a400m"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_dense, aux_d = moe_lib.moe_mlp(params, x, cfg, compute_dtype=jnp.float32)
    y_sorted, aux_s = moe_lib.moe_mlp_sorted(params, x, cfg,
                                             compute_dtype=jnp.float32)
    np.testing.assert_allclose(y_dense, y_sorted, atol=1e-5)
    np.testing.assert_allclose(aux_d, aux_s, atol=1e-6)


def test_sorted_dispatch_differentiable():
    cfg = reduced(get_model_config("granite-moe-1b-a400m"))
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    g = jax.grad(
        lambda p: moe_lib.moe_mlp_sorted(p, x, cfg, jnp.float32)[0].sum())(params)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
    assert float(sum(jnp.abs(l).sum() for l in jax.tree.leaves(g))) > 0


# ---------------------------------------------------------------------------
# Evaluation metrics
# ---------------------------------------------------------------------------

def test_group_metrics_shapes():
    cfg = reduced(get_model_config("qwen2-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    dm = make_data_model(jax.random.PRNGKey(1), vocab_size=cfg.vocab_size,
                         num_groups=4, num_clients=2, alpha=0.3)
    b = sample_client_batch(dm, jax.random.PRNGKey(2), 0, 2, 16)
    m = group_metrics(params, b, cfg, num_groups=4, compute_dtype=jnp.float32)
    assert m["group_loss"].shape == (4,)
    assert float(m["worst_group_loss"]) >= float(m["mean_loss"]) - 1e-5
    assert 1 <= int(m["groups_present"]) <= 4


# ---------------------------------------------------------------------------
# bf16 corrections + time-varying topology
# ---------------------------------------------------------------------------

def _quad_setup(cfg, K=4, n=8):
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, n, dx=10, dy=5, heterogeneity=2.0)
    prob = quadratic_problem(data, sigma=0.0)
    cb = {k: v for k, v in data.items() if k != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), cb)
    st = init_state(prob, cfg, key, init_batch=cb,
                    init_keys=jax.random.split(key, n))
    return prob, st, jax.jit(make_round_step(prob, cfg)), kb


def test_bf16_corrections_still_converge():
    n, K = 8, 4
    cfg = AlgorithmConfig(num_clients=n, local_steps=K, eta_cx=0.01,
                          eta_cy=0.1, eta_sx=0.5, eta_sy=0.5, topology="ring",
                          correction_dtype="bfloat16")
    prob, st, step, kb = _quad_setup(cfg, K, n)
    assert jax.tree.leaves(st.cx)[0].dtype == jnp.bfloat16
    for t in range(300):
        keys = jax.random.split(jax.random.PRNGKey(t), K * n).reshape(K, n, 2)
        st = step(st, kb, keys)
    # bf16 corrections quantize the tracking state, flooring ||grad Phi|| at
    # ~0.3 on this problem (fp32 reaches ~0.02); assert convergence to that
    # noise floor, not to the fp32 optimum.
    assert float(diagnostics(prob, st)["phi_grad_norm"]) < 0.5


def test_topology_cycle_converges_faster_than_worst_member():
    """Alternating ring/exp gossip: convergence should land between the
    static ring and static exp topologies (changing-topology regime)."""
    n, K = 16, 4
    results = {}
    for label, topo, cycle in (("ring", "ring", ()),
                               ("cycle", "ring", ("ring", "exp")),
                               ("exp", "exp", ())):
        cfg = AlgorithmConfig(num_clients=n, local_steps=K, eta_cx=0.01,
                              eta_cy=0.1, eta_sx=0.6, eta_sy=0.6,
                              topology=topo, topology_cycle=cycle)
        prob, st, step, kb = _quad_setup(cfg, K, n)
        for t in range(120):
            keys = jax.random.split(jax.random.PRNGKey(t), K * n).reshape(K, n, 2)
            st = step(st, kb, keys)
        results[label] = float(diagnostics(prob, st)["phi_grad_norm"])
    assert results["cycle"] <= results["ring"] + 1e-3
    assert all(np.isfinite(v) for v in results.values())
