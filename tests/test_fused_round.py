"""Parity + protocol suite for the whole-round kernel and compressed gossip.

Three contracts:

* ``kernels.ops.fused_round`` (interpret-mode Pallas) matches the
  ``kernels.ref.fused_round_ref`` oracle to ≤1e-6 on arbitrary unaligned
  shapes, for every compress method × gossip dtype.
* ``mixing_impl="fused_round"`` routed through ``make_round_step``
  reproduces the dense per-leaf round across all four algorithm variants,
  lr schedules, stochastic-gradient noise, and churn (sampled W +
  participation masks).
* The error-feedback compression protocol: the residual identity
  ``Q(v) + e = v`` is bit-exact (Sterbenz), the EF state survives an
  engine checkpoint bit-exactly, and 100 compressed rounds stay within a
  tight relative divergence of the exact trajectory.

Cross-lowering trajectories are NOT compared under compression: fused and
pallas_packed compute Δ with ~1e-7 op-order differences that int8
``round()`` amplifies near quantization boundaries — the invariant suite
(Σc = 0, divergence bound, same-lowering parity) is the correct contract.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs.base import AlgorithmConfig
from repro.core import (
    init_state,
    make_quadratic_data,
    make_round_step,
    quadratic_problem,
)
from repro.core import compression, mixing, stochastic_topology as stoch
from repro.core import topology
from repro.kernels import ops
from repro.kernels.quantize import QUANT_METHODS, wire_bits

ALGOS = ("kgt_minimax", "dsgda", "local_sgda", "gt_gda")


# ---------------------------------------------------------------------------
# kernel (interpret) vs oracle, raw operands
# ---------------------------------------------------------------------------

def _kernel_operands(n=6, dz=150, k=3, seed=0):
    """Deliberately unaligned (n % 8 != 0, dz % 128 != 0)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    w = jnp.asarray(topology.mixing_matrix("ring", n), jnp.float32)
    # O(0.1-ish) operands: the contract is ≤1e-6 *absolute*, so keep the
    # matvec reductions (dz- and n-length f32 sums whose op order differs
    # between the kernel and the oracle) from inflating the noise floor
    z0 = jax.random.normal(ks[0], (n, dz), jnp.float32) * 0.3
    c = jax.random.normal(ks[1], (n, dz), jnp.float32) * 0.1
    ef = jax.random.normal(ks[2], (n, dz), jnp.float32) * 0.01
    g = jax.random.normal(ks[3], (n, dz, dz), jnp.float32) * (0.1 / dz)
    h = jax.random.normal(ks[4], (k, n, dz), jnp.float32) * 0.05
    step = jnp.full((n, dz), 0.05, jnp.float32)
    etas = jnp.full((n, dz), 0.5, jnp.float32)
    corr = jnp.broadcast_to(
        jax.random.normal(ks[5], (dz,), jnp.float32) * 0.3, (n, dz))
    mask = jnp.ones((n, dz), jnp.float32)
    return w, z0, c, ef, g, h, step, etas, corr, mask


@pytest.mark.parametrize("gossip_dtype", [None, "bfloat16"])
@pytest.mark.parametrize("compress", [None, "bf16", "int8"])
def test_fused_round_kernel_matches_oracle(compress, gossip_dtype):
    args = _kernel_operands(seed=hash((compress, gossip_dtype)) % 97)
    outs = {}
    for backend in ("interpret", "xla"):
        outs[backend] = ops.fused_round(*args, backend=backend,
                                        compress=compress,
                                        gossip_dtype=gossip_dtype)
    for a, b in zip(outs["interpret"], outs["xla"]):
        np.testing.assert_allclose(a, b, rtol=0, atol=1e-6)


def test_fused_round_rejects_oversized_state():
    """VMEM guard: the whole-round kernel holds G = (n, dz, dz) resident,
    so dz beyond one block must fail loudly, not silently spill."""
    n, dz, k = 4, 1100, 1  # pads past the 1024 single-block ceiling
    z = jnp.zeros((n, dz))
    with pytest.raises(ValueError, match="fused_round"):
        ops.fused_round(jnp.eye(n), z, z, z, jnp.zeros((n, dz, dz)),
                        jnp.zeros((k, n, dz)), z, jnp.zeros((dz,)),
                        jnp.zeros((dz,)), jnp.ones((n,)),
                        backend="interpret")


# ---------------------------------------------------------------------------
# round_step routing: fused_round vs the dense per-leaf reference
# ---------------------------------------------------------------------------

def _round_setup(algo, impl, backend, n=8, K=4, topo="ring", sigma=0.0,
                 compress=None, lr_scale=None, **mk_kwargs):
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, n, dx=10, dy=5, heterogeneity=2.0)
    prob = quadratic_problem(data, sigma=sigma)
    cfg = AlgorithmConfig(algorithm=algo, num_clients=n, local_steps=K,
                          eta_cx=0.01, eta_cy=0.1, eta_sx=0.5, eta_sy=0.5,
                          topology=topo, mixing_impl=impl,
                          gossip_backend=backend, gossip_compress=compress)
    cb = {k: v for k, v in data.items() if k != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), cb)
    st = init_state(prob, cfg, key, init_batch=cb,
                    init_keys=jax.random.split(key, n))
    step = jax.jit(make_round_step(prob, cfg, lr_scale=lr_scale, **mk_kwargs))
    return st, step, kb, (n, K)


def _run_rounds(algo, impl, backend, rounds=5, **kw):
    st, step, kb, (n, K) = _round_setup(algo, impl, backend, **kw)
    for t in range(rounds):
        keys = jax.random.split(jax.random.PRNGKey(t), K * n).reshape(K, n, 2)
        st = step(st, kb, keys)
    return st


def _assert_state_close(a_state, b_state, atol, msg=""):
    for name in ("x", "y", "cx", "cy"):
        # corrections carry the ±1/(K·η_c) scale (up to 100 at these etas),
        # which amplifies the f32 op-order noise floor by the same factor
        tol = atol * (4 if name in ("cx", "cy") else 1)
        for a, b in zip(jax.tree.leaves(getattr(a_state, name)),
                        jax.tree.leaves(getattr(b_state, name))):
            np.testing.assert_allclose(a, b, rtol=0, atol=tol,
                                       err_msg=f"{msg}{name}")


@pytest.mark.parametrize("backend", ["interpret", "xla"])
@pytest.mark.parametrize("algo", ALGOS)
def test_fused_round_matches_dense_all_variants(algo, backend):
    dense = _run_rounds(algo, "dense", "auto")
    fused = _run_rounds(algo, "fused_round", backend)
    _assert_state_close(dense, fused, 5e-6, msg=f"{algo}/{backend}/")


def test_fused_round_with_noise_matches_dense():
    """σ > 0: the affine oracle must split the noise key exactly like the
    autodiff value path, so identical keys give identical trajectories."""
    dense = _run_rounds("kgt_minimax", "dense", "auto", sigma=0.3)
    fused = _run_rounds("kgt_minimax", "fused_round", "xla", sigma=0.3)
    _assert_state_close(dense, fused, 5e-6)


def test_fused_round_with_lr_schedule():
    sched = lambda r: 1.0 / (1.0 + 0.1 * r.astype(jnp.float32))
    dense = _run_rounds("kgt_minimax", "dense", "auto", lr_scale=sched)
    fused = _run_rounds("kgt_minimax", "fused_round", "interpret",
                        lr_scale=sched)
    _assert_state_close(dense, fused, 5e-6)


@pytest.mark.parametrize("backend", ["interpret", "xla"])
@pytest.mark.parametrize("family", ["erdos_renyi", "dropout"])
def test_fused_round_matches_dense_under_churn(family, backend):
    """Sampled W + participation mask as traced operands: the whole-round
    kernel must zero inactive clients' local steps, drop their links, and
    freeze their (θ, c) exactly like the dense round."""
    outs = {}
    for impl, be in (("dense", "auto"), ("fused_round", backend)):
        st, step, kb, (n, K) = _round_setup("kgt_minimax", impl, be, n=8,
                                            topo="full", traced_w=True,
                                            participation=True)
        w_fn = stoch.make_w_sampler(
            family, n, jax.random.PRNGKey(11),
            base_w=topology.mixing_matrix("full", n), edge_prob=0.5,
            client_drop_prob=0.3)
        mask_fn = stoch.make_participation_sampler(n, jax.random.PRNGKey(13),
                                                   0.7)
        for t in range(4):
            keys = jax.random.split(jax.random.PRNGKey(t),
                                    K * n).reshape(K, n, 2)
            st = step(st, kb, keys, w_fn(jnp.int32(t)), mask_fn(jnp.int32(t)))
        outs[impl] = st
    _assert_state_close(outs["dense"], outs["fused_round"], 5e-6,
                        msg=f"{family}/{backend}/")


# ---------------------------------------------------------------------------
# error-feedback compression protocol
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", QUANT_METHODS)
def test_ef_residual_identity_bitwise(method):
    """Q(v) + e == v exactly in f32 (Sterbenz for bf16 truncation; exact
    subtraction around the shared per-row scale for int8) — the property
    that makes error feedback lossless over time, not just approximately."""
    key = jax.random.PRNGKey(3)
    delta = jax.random.normal(key, (8, 257), jnp.float32) * \
        jnp.exp(jax.random.normal(jax.random.fold_in(key, 1), (8, 257)) * 3)
    ef = jax.random.normal(jax.random.fold_in(key, 2), (8, 257),
                           jnp.float32) * 0.1
    q, e_new = compression.ef_transmit(delta, ef, method)
    np.testing.assert_array_equal(np.asarray(q + e_new),
                                  np.asarray(delta + ef))
    assert wire_bits(method) in (8, 16)


@pytest.mark.parametrize("method", QUANT_METHODS)
def test_ef_transmit_masked_rows_hold_residual(method):
    """Inactive clients transmit Q(0) = 0 and their residual is untouched —
    churn must not leak or destroy banked compression error."""
    key = jax.random.PRNGKey(5)
    delta = jax.random.normal(key, (6, 64), jnp.float32)
    ef = jax.random.normal(jax.random.fold_in(key, 1), (6, 64), jnp.float32)
    mask = jnp.asarray([1, 0, 1, 0, 0, 1], jnp.float32)
    q, e_new = compression.ef_transmit(delta, ef, method, mask=mask)
    inactive = ~np.asarray(mask, bool)
    np.testing.assert_array_equal(np.asarray(q)[inactive], 0.0)
    np.testing.assert_array_equal(np.asarray(e_new)[inactive],
                                  np.asarray(ef)[inactive])


@pytest.mark.parametrize("impl,backend", [("pallas_packed", "xla"),
                                          ("fused_round", "xla"),
                                          ("fused_round", "interpret")])
@pytest.mark.parametrize("method", QUANT_METHODS)
def test_sum_c_zero_under_compressed_gossip(impl, backend, method):
    """The same transmitted q rides the correction AND the mixing, so
    Lemma 8's Σ_i c_i = 0 telescopes exactly through lossy quantization."""
    st = _run_rounds("kgt_minimax", impl, backend, rounds=5, compress=method)
    for c in (st.cx, st.cy):
        mean_c = jax.tree.leaves(jax.tree.map(lambda v: v.mean(0), c))[0]
        assert float(jnp.abs(mean_c).max()) < 1e-5, (impl, method)


def test_compressed_vs_exact_divergence_bounded():
    """100 int8-compressed rounds track the exact trajectory: EF keeps the
    quantization error from accumulating — divergence stays near the f32
    noise floor instead of growing with the round count."""
    exact = _run_rounds("kgt_minimax", "fused_round", "xla", rounds=100)
    comp = _run_rounds("kgt_minimax", "fused_round", "xla", rounds=100,
                       compress="int8")
    for name in ("x", "y"):
        a = jax.tree.leaves(getattr(exact, name))[0]
        b = jax.tree.leaves(getattr(comp, name))[0]
        rel = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-12))
        assert rel < 1e-3, (name, rel)


def test_checkpoint_roundtrips_ef_state_bitexact(tmp_path):
    """The EF residual is algorithm state: dropping it at restore would
    replay banked error into the next transmit.  Round-trip through the
    engine checkpoint must be bit-exact, and resuming must produce the
    exact same next state as never having checkpointed."""
    st, step, kb, (n, K) = _round_setup("kgt_minimax", "fused_round", "xla",
                                        compress="int8")
    for t in range(3):
        keys = jax.random.split(jax.random.PRNGKey(t), K * n).reshape(K, n, 2)
        st = step(st, kb, keys)
    assert st.ef_x is not None and st.ef_y is not None
    assert float(jnp.abs(st.ef_x).max()) > 0  # int8 actually banked error
    path = str(tmp_path / "ef_ckpt")
    ckpt_lib.save(path, st)
    st2 = ckpt_lib.restore(path, st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    keys = jax.random.split(jax.random.PRNGKey(9), K * n).reshape(K, n, 2)
    out1, out2 = step(st, kb, keys), step(st2, kb, keys)
    for a, b in zip(jax.tree.leaves(out1), jax.tree.leaves(out2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_scan_carries_ef_state_bitexact():
    """The scan engine is pytree-generic: a chunked run of the fused round
    with int8 EF gossip must be bit-identical to the per-round host loop,
    EF residual leaves included — compression adds state, not special
    cases, to the engine."""
    from repro.engine import engine as engine_lib
    from repro.engine import sampler as sampler_lib

    st, step, kb, (n, K) = _round_setup("kgt_minimax", "fused_round", "xla",
                                        compress="int8")
    sampler = sampler_lib.make_fixed_batch_sampler(
        kb, local_steps=K, num_clients=n, seed=3)
    chunk = jax.jit(engine_lib.chunk_program(step, sampler, None, length=6),
                    donate_argnums=())
    scanned, _ = chunk(st, jnp.int32(5))
    host = st
    for t in range(6):
        batches, keys = sampler(host.round)
        host = step(host, batches, keys)
    assert scanned.ef_x is not None
    for a, b in zip(jax.tree.leaves(scanned), jax.tree.leaves(host)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncompressed_state_has_no_ef_leaves():
    """gossip_compress=None must not change the state pytree: old
    checkpoints and the engine's donated-buffer layout stay valid."""
    st, _, _, _ = _round_setup("kgt_minimax", "pallas_packed", "xla")
    assert st.ef_x is None and st.ef_y is None


# ---------------------------------------------------------------------------
# configuration validation — loud rejections, no silent fallbacks
# ---------------------------------------------------------------------------

def test_compress_requires_packed_impl():
    with pytest.raises(ValueError, match="gossip_compress"):
        _round_setup("kgt_minimax", "dense", "auto", compress="int8")


def test_fused_round_requires_affine_coeffs():
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, 4, dx=6, dy=3)
    prob = dataclasses.replace(quadratic_problem(data), affine_coeffs=None)
    cfg = AlgorithmConfig(num_clients=4, local_steps=2, eta_cx=0.01,
                          eta_cy=0.05, mixing_impl="fused_round",
                          gossip_backend="xla")
    with pytest.raises(ValueError, match="affine"):
        make_round_step(prob, cfg)


def test_fused_round_rejects_byzantine():
    with pytest.raises(ValueError, match="byzantine|adversary"):
        _round_setup("kgt_minimax", "fused_round", "xla", byzantine=True)


def test_fused_round_has_no_standalone_mixer():
    with pytest.raises(ValueError, match="fused_round"):
        mixing.make_mixer("full", "fused_round", np.eye(4, dtype=np.float32))


def test_validate_method():
    assert compression.validate_method(None) is None
    assert compression.validate_method("none") is None
    assert compression.validate_method("int8") == "int8"
    with pytest.raises(ValueError, match="int4"):
        compression.validate_method("int4")
