"""Parity suite for the fused-gossip round engine.

The Pallas kernel (interpret mode) must match the pure-jnp oracle to ≤1e-6
across every topology, client count, and gossip dtype, including ragged-D
tile padding; the packed round_step must reproduce the dense per-leaf round.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AlgorithmConfig
from repro.core import (
    init_state,
    make_quadratic_data,
    make_round_step,
    quadratic_problem,
)
from repro.core import packing, stochastic_topology as stoch, topology
from repro.kernels import ops, ref

TOPOLOGIES = ("ring", "torus", "full", "exp")
CLIENT_COUNTS = (1, 2, 4, 8)
# torus only exists for square client counts — parametrized explicitly
# (no silent skips; the constructor raising on non-square n is asserted
# below and in test_topology.py)
SQUARE_CLIENT_COUNTS = tuple(n for n in CLIENT_COUNTS
                             if int(round(np.sqrt(n))) ** 2 == n)
TOPO_CLIENTS = tuple(
    (t, n) for t in TOPOLOGIES
    for n in (SQUARE_CLIENT_COUNTS if t == "torus" else CLIENT_COUNTS))


def _operands(n, d, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    delta = jax.random.normal(ks[0], (n, d), jnp.float32)
    theta = jax.random.normal(ks[1], (n, d), jnp.float32) * 3.0
    c = jax.random.normal(ks[2], (n, d), jnp.float32) * 0.5
    return delta, theta, c


# ---------------------------------------------------------------------------
# kernel (interpret) vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gossip_dtype", [None, "bfloat16"])
@pytest.mark.parametrize("topo,n", TOPO_CLIENTS)
def test_kernel_matches_oracle(topo, n, gossip_dtype):
    w = topology.mixing_matrix(topo, n)
    d = 384 + n  # not a lane/block multiple for most n
    delta, theta, c = _operands(n, d, seed=n)
    args = (w, delta, theta, c, 0.7, 4.2)
    t_k, c_k = ops.fused_gossip_round(
        *args, backend="interpret", gossip_dtype=gossip_dtype)
    t_r, c_r = ops.fused_gossip_round(
        *args, backend="xla", gossip_dtype=gossip_dtype)
    np.testing.assert_allclose(t_k, t_r, rtol=0, atol=1e-6)
    np.testing.assert_allclose(c_k, c_r, rtol=0, atol=1e-6)


@pytest.mark.parametrize("d", [1, 127, 128, 513, 640])
def test_kernel_ragged_d_tile_padding(d):
    """D far from, at, and just past the 128-lane/512-block boundaries."""
    n = 4
    w = topology.mixing_matrix("exp", n)
    delta, theta, c = _operands(n, d, seed=d)
    t_k, c_k = ops.fused_gossip_round(w, delta, theta, c, 1.3, -2.0,
                                      backend="interpret")
    t_r, c_r = ops.fused_gossip_round(w, delta, theta, c, 1.3, -2.0,
                                      backend="xla")
    assert t_k.shape == c_k.shape == (n, d)
    np.testing.assert_allclose(t_k, t_r, rtol=0, atol=1e-6)
    np.testing.assert_allclose(c_k, c_r, rtol=0, atol=1e-6)


def test_oracle_math_against_handwritten():
    """The oracle itself computes Wθ + η_s·WΔ and c + s·(Δ − WΔ)."""
    n, d = 4, 16
    w = topology.mixing_matrix("ring", n)
    delta, theta, c = _operands(n, d)
    eta_s, s = 0.5, 2.0
    t_r, c_r = ref.fused_gossip_ref(w, delta, theta, c, eta_s, s)
    wd = np.asarray(w, np.float32) @ np.asarray(delta)
    wt = np.asarray(w, np.float32) @ np.asarray(theta)
    np.testing.assert_allclose(t_r, wt + eta_s * wd, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(c_r, np.asarray(c) + s * (np.asarray(delta) - wd),
                               rtol=1e-6, atol=1e-6)


def test_torus_gossip_rejects_nonsquare_client_count():
    """No silent skip: asking for a torus over a non-square client count is
    a configuration error the constructor reports loudly."""
    for n in (2, 8):
        with pytest.raises(ValueError, match="square"):
            topology.mixing_matrix("torus", n)


@pytest.mark.parametrize("masked", [False, True])
@pytest.mark.parametrize("family", ["erdos_renyi", "pairwise", "dropout"])
def test_kernel_matches_oracle_sampled_w(family, masked):
    """Traced-W parity: per-round *sampled* mixing matrices (every
    stochastic topology family), optionally participation-masked, through
    the interpret kernel vs the xla oracle — the W operand is traced on
    both paths (ops.fused_gossip_round takes it as a jit argument), so this
    mirrors the static-topology grid above for the churn tentpole."""
    n, d = 8, 384 + 8
    w_fn = stoch.make_w_sampler(
        family, n, jax.random.PRNGKey(7),
        base_w=topology.mixing_matrix("exp", n), edge_prob=0.4,
        client_drop_prob=0.3)
    mask_fn = stoch.make_participation_sampler(n, jax.random.PRNGKey(9), 0.6)
    for r in (0, 3):
        w = w_fn(jnp.int32(r))
        if masked:
            w = stoch.masked_w(w, mask_fn(jnp.int32(r)))
        delta, theta, c = _operands(n, d, seed=r)
        args = (w, delta, theta, c, 0.7, 4.2)
        t_k, c_k = ops.fused_gossip_round(*args, backend="interpret")
        t_r, c_r = ops.fused_gossip_round(*args, backend="xla")
        np.testing.assert_allclose(t_k, t_r, rtol=0, atol=1e-6)
        np.testing.assert_allclose(c_k, c_r, rtol=0, atol=1e-6)


def test_resolve_gossip_backend_validates():
    assert ops.resolve_gossip_backend("interpret") == "interpret"
    assert ops.resolve_gossip_backend("auto") in ("pallas", "xla")
    with pytest.raises(ValueError, match="unknown gossip_backend"):
        ops.resolve_gossip_backend("interperet")


def test_corr_scale_zero_passes_c_through():
    n, d = 2, 64
    w = topology.mixing_matrix("full", n)
    delta, theta, c = _operands(n, d)
    _, c_k = ops.fused_gossip_round(w, delta, theta, c, 1.0, 0.0,
                                    backend="interpret")
    np.testing.assert_allclose(c_k, c, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# packing round-trip
# ---------------------------------------------------------------------------

def test_pack_unpack_roundtrip_preserves_dtype_and_shape():
    n = 4
    tree = {
        "a": jnp.arange(n * 6, dtype=jnp.float32).reshape(n, 3, 2),
        "b": {"w": jnp.ones((n, 5), jnp.bfloat16),
              "v": jnp.full((n,), 2.0, jnp.float32)},
    }
    spec = packing.pack_spec(tree)
    buf = packing.pack(tree, spec)
    assert buf.shape == (n, 6 + 5 + 1) and buf.dtype == jnp.float32
    out = packing.unpack(buf, spec)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_pack_rejects_mismatched_leading_dim():
    with pytest.raises(ValueError):
        packing.pack_spec({"a": jnp.zeros((4, 2)), "b": jnp.zeros((3, 2))})


# ---------------------------------------------------------------------------
# packed round_step vs dense per-leaf round
# ---------------------------------------------------------------------------

def _round_setup(algo, impl, backend, n=8, K=4, topo="ring"):
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, n, dx=10, dy=5, heterogeneity=2.0)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(algorithm=algo, num_clients=n, local_steps=K,
                          eta_cx=0.01, eta_cy=0.1, eta_sx=0.5, eta_sy=0.5,
                          topology=topo, mixing_impl=impl,
                          gossip_backend=backend)
    cb = {k: v for k, v in data.items() if k != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), cb)
    st = init_state(prob, cfg, key, init_batch=cb,
                    init_keys=jax.random.split(key, n))
    return st, jax.jit(make_round_step(prob, cfg)), kb, (n, K)


def _run_rounds(algo, impl, backend, rounds=5, topo="ring", n=8):
    st, step, kb, (n, K) = _round_setup(algo, impl, backend, n=n, topo=topo)
    for t in range(rounds):
        keys = jax.random.split(jax.random.PRNGKey(t), K * n).reshape(K, n, 2)
        st = step(st, kb, keys)
    return st


@pytest.mark.parametrize("algo", ["kgt_minimax", "dsgda", "local_sgda", "gt_gda"])
def test_packed_round_matches_dense_all_variants(algo):
    dense = _run_rounds(algo, "dense", "auto")
    packed = _run_rounds(algo, "pallas_packed", "interpret")
    for name in ("x", "y", "cx", "cy"):
        for a, b in zip(jax.tree.leaves(getattr(dense, name)),
                        jax.tree.leaves(getattr(packed, name))):
            np.testing.assert_allclose(a, b, rtol=0, atol=1e-6, err_msg=name)


@pytest.mark.parametrize("topo", ["torus", "exp", "full"])
def test_packed_round_matches_dense_topologies(topo):
    n = 4  # square, so torus is valid
    dense = _run_rounds("kgt_minimax", "dense", "auto", topo=topo, n=n)
    packed = _run_rounds("kgt_minimax", "pallas_packed", "xla", topo=topo, n=n)
    for a, b in zip(jax.tree.leaves(dense.x), jax.tree.leaves(packed.x)):
        np.testing.assert_allclose(a, b, rtol=0, atol=1e-6)


def test_packed_round_with_lr_schedule_and_bf16_gossip():
    """Traced correction scale (lr schedule) + narrowed gossip operands."""
    n, K = 4, 2
    key = jax.random.PRNGKey(1)
    data = make_quadratic_data(key, n, dx=6, dy=3)
    prob = quadratic_problem(data, sigma=0.0)
    sched = lambda r: 1.0 / (1.0 + 0.1 * r.astype(jnp.float32))
    outs = {}
    for impl, backend in (("dense", "auto"), ("pallas_packed", "interpret")):
        cfg = AlgorithmConfig(num_clients=n, local_steps=K, eta_cx=0.01,
                              eta_cy=0.05, topology="ring", mixing_impl=impl,
                              gossip_dtype="bfloat16", gossip_backend=backend)
        cb = {k: v for k, v in data.items() if k != "mu"}
        kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), cb)
        st = init_state(prob, cfg, key, init_batch=cb,
                        init_keys=jax.random.split(key, n))
        step = jax.jit(make_round_step(prob, cfg, lr_scale=sched))
        for t in range(3):
            keys = jax.random.split(jax.random.PRNGKey(t), K * n).reshape(K, n, 2)
            st = step(st, kb, keys)
        outs[impl] = st
    for a, b in zip(jax.tree.leaves(outs["dense"].x),
                    jax.tree.leaves(outs["pallas_packed"].x)):
        # bf16 gossip rounds differently through the packed buffer; the
        # kernel-vs-oracle contract stays ≤1e-6 (tests above) — across
        # lowerings only the gossip-dtype noise floor applies.
        np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)
    for impl in outs:
        # bf16 gossip breaks exact mean-WΔ cancellation, so Lemma 8's Σc = 0
        # only holds to the bf16 noise floor (same for dense and packed).
        mean_c = jax.tree.leaves(outs[impl].cx)[0].mean(0)
        assert float(jnp.abs(mean_c).max()) < 2e-2


def test_packed_round_topology_cycle():
    """Time-varying W: the packed path must pick W per round, like dense."""
    n, K = 4, 2
    key = jax.random.PRNGKey(2)
    data = make_quadratic_data(key, n, dx=5, dy=3)
    prob = quadratic_problem(data, sigma=0.0)
    outs = {}
    for impl in ("dense", "pallas_packed"):
        cfg = AlgorithmConfig(num_clients=n, local_steps=K, eta_cx=0.01,
                              eta_cy=0.05, mixing_impl=impl,
                              gossip_backend="xla",
                              topology_cycle=("ring", "full"))
        cb = {k: v for k, v in data.items() if k != "mu"}
        kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), cb)
        st = init_state(prob, cfg, key, init_batch=cb,
                        init_keys=jax.random.split(key, n))
        step = jax.jit(make_round_step(prob, cfg))
        for t in range(4):
            keys = jax.random.split(jax.random.PRNGKey(t), K * n).reshape(K, n, 2)
            st = step(st, kb, keys)
        outs[impl] = st
    for a, b in zip(jax.tree.leaves(outs["dense"].x),
                    jax.tree.leaves(outs["pallas_packed"].x)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", ["xla", "interpret"])
@pytest.mark.parametrize("family", ["erdos_renyi", "pairwise", "dropout"])
def test_packed_round_matches_dense_under_churn(family, backend):
    """Full round_step parity under the churn tentpole: a per-round sampled
    W *and* a per-round participation mask, fed as traced operands to both
    the dense per-leaf round and the packed fused epilogue (xla oracle and
    interpret kernel) — identical draws, matching trajectories."""
    n, K = 4, 2
    key = jax.random.PRNGKey(5)
    data = make_quadratic_data(key, n, dx=6, dy=3, heterogeneity=1.5)
    prob = quadratic_problem(data, sigma=0.0)
    w_fn = stoch.make_w_sampler(
        family, n, jax.random.PRNGKey(11),
        base_w=topology.mixing_matrix("full", n), edge_prob=0.5,
        client_drop_prob=0.3)
    mask_fn = stoch.make_participation_sampler(n, jax.random.PRNGKey(11), 0.7)
    outs = {}
    for impl in ("dense", "pallas_packed"):
        cfg = AlgorithmConfig(num_clients=n, local_steps=K, eta_cx=0.01,
                              eta_cy=0.1, eta_sx=0.5, eta_sy=0.5,
                              topology="full", mixing_impl=impl,
                              gossip_backend=backend)
        cb = {k: v for k, v in data.items() if k != "mu"}
        kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), cb)
        st = init_state(prob, cfg, key, init_batch=cb,
                        init_keys=jax.random.split(key, n))
        step = jax.jit(make_round_step(prob, cfg, traced_w=True,
                                       participation=True))
        for t in range(4):
            keys = jax.random.split(jax.random.PRNGKey(t), K * n).reshape(K, n, 2)
            st = step(st, kb, keys, w_fn(jnp.int32(t)), mask_fn(jnp.int32(t)))
        outs[impl] = st
    for name in ("x", "y", "cx", "cy"):
        for a, b in zip(jax.tree.leaves(getattr(outs["dense"], name)),
                        jax.tree.leaves(getattr(outs["pallas_packed"], name))):
            np.testing.assert_allclose(a, b, rtol=0, atol=1e-6,
                                       err_msg=f"{family}/{backend}/{name}")
