"""The loop-aware HLO cost parser vs known-cost programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_cost


def _analyze(fn, *sds):
    return hlo_cost.analyze(jax.jit(fn).lower(*sds).compile().as_text())


def test_single_matmul_exact():
    s = jax.ShapeDtypeStruct((128, 96), jnp.float32)
    t = jax.ShapeDtypeStruct((96, 64), jnp.float32)
    c = _analyze(lambda a, b: a @ b, s, t)
    assert c.dot_flops == pytest.approx(2 * 128 * 96 * 64)


def test_scan_multiplies_by_trip_count():
    """A nonlinear scan body forces the forward to stay live: flops must scale
    with the trip count, which XLA's own cost_analysis misses."""
    n, d = 7, 64

    def f(w, xs):
        def body(c, x):
            return jnp.tanh(c @ x), ()
        c, _ = jax.lax.scan(body, w, xs)
        return c

    w = jax.ShapeDtypeStruct((d, d), jnp.float32)
    xs = jax.ShapeDtypeStruct((n, d, d), jnp.float32)
    c = _analyze(f, w, xs)
    assert c.dot_flops == pytest.approx(n * 2 * d**3, rel=0.01)


def test_nested_scan_multiplicity():
    n_out, n_in, d = 3, 4, 32

    def f(w, xs):
        def inner(c, x):
            return jnp.tanh(c @ x), ()

        def outer(c, xs_i):
            c2, _ = jax.lax.scan(inner, c, xs_i)
            return c2, ()

        c, _ = jax.lax.scan(outer, w, xs)
        return c

    w = jax.ShapeDtypeStruct((d, d), jnp.float32)
    xs = jax.ShapeDtypeStruct((n_out, n_in, d, d), jnp.float32)
    c = _analyze(f, w, xs)
    assert c.dot_flops == pytest.approx(n_out * n_in * 2 * d**3, rel=0.01)


def test_collectives_counted_with_shapes():
    import os
    # collective bytes over an 8-way mesh (device count fixed by conftest env
    # only in dryrun; here use whatever single device -> psum lowers away).
    # Instead check parse robustness on a synthetic HLO snippet:
    txt = """
HloModule m

ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128]{1,0} parameter(0)
  ROOT %ag = f32[16,128]{1,0} all-gather(%p), dimensions={0}
}
"""
    c = hlo_cost.analyze(txt)
    assert c.collective_bytes["all-gather"] == 16 * 128 * 4


def test_traffic_counts_fusion_boundary_only():
    # one fused elementwise chain: traffic ~ inputs + outputs, not internals
    def f(a):
        return jnp.tanh(a * 2.0 + 1.0) * a

    s = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _analyze(f, s)
    nbytes = 1024 * 1024 * 4
    assert c.traffic_bytes <= 6 * nbytes  # a couple of reads + one write
