"""Pallas kernels (interpret mode) vs pure-jnp oracles — shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, rglru_scan, ssd_scan

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,s,h,kv,d", [
    (2, 256, 4, 2, 64),
    (1, 300, 4, 1, 64),     # non-multiple seq (padding path), MQA
    (2, 128, 8, 8, 128),    # MHA, lane-width head dim
    (1, 128, 2, 2, 32),
])
@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, s, h, kv, d, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    out = flash_attention(q, k, v, window=window, backend="interpret")
    ref = flash_attention(q, k, v, window=window, backend="xla")
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 100, 2, 64, 128, 64),  # padding path
    (2, 64, 8, 16, 32, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(KEY, 4)
    xdt = (jax.random.normal(ks[0], (b, s, h, p)) * 0.5).astype(dtype)
    loga = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.3
    bm = (jax.random.normal(ks[2], (b, s, n)) * 0.3).astype(dtype)
    cm = (jax.random.normal(ks[3], (b, s, n)) * 0.3).astype(dtype)
    out = ssd_scan(xdt, loga, bm, cm, chunk=chunk, backend="interpret")
    ref = ssd_scan(xdt, loga, bm, cm, chunk=chunk, backend="xla")
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,s,w,chunk", [
    (2, 256, 64, 64),
    (1, 200, 128, 256),   # padding path
    (3, 64, 32, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_matches_ref(b, s, w, chunk, dtype):
    ks = jax.random.split(KEY, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, w))).astype(dtype)
    u = (jax.random.normal(ks[1], (b, s, w)) * 0.5).astype(dtype)
    out = rglru_scan(a, u, chunk=chunk, backend="interpret")
    ref = rglru_scan(a, u, chunk=chunk, backend="xla")
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol)


def test_flash_attention_q_longer_than_kv_groups():
    """GQA group indexing: 8 q heads sharing 2 kv heads gives the same result
    as explicit repetition."""
    b, s, h, kv, d = 1, 128, 8, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    out = flash_attention(q, k, v, backend="interpret")
    k_rep = jnp.repeat(k, h // kv, axis=2)
    v_rep = jnp.repeat(v, h // kv, axis=2)
    ref = flash_attention(q, k_rep, v_rep, backend="xla")
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,d,v,bt,bv", [
    (64, 32, 500, 32, 128),    # padded vocab path
    (100, 64, 1024, 128, 512), # padded token path
    (32, 16, 128, 32, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_cross_entropy_matches_ref(n, d, v, bt, bv, dtype):
    from repro.kernels import fused_cross_entropy
    ks = jax.random.split(KEY, 3)
    hidden = jax.random.normal(ks[0], (n, d), dtype)
    weight = jax.random.normal(ks[1], (v, d), dtype) * 0.1
    labels = jax.random.randint(ks[2], (n,), 0, v)
    out = fused_cross_entropy(hidden, weight, labels, block_t=bt, block_v=bv,
                              backend="interpret")
    ref = fused_cross_entropy(hidden, weight, labels, backend="xla")
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)
