"""Algorithm-level tests of K-GT-Minimax (Algorithm 1) and baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AlgorithmConfig
from repro.core import (
    KGTState,
    diagnostics,
    init_state,
    make_quadratic_data,
    make_round_step,
    mean_over_clients,
    mixing_matrix,
    quadratic_problem,
)


def _setup(n=8, K=4, sigma=0.0, heterogeneity=1.0, topology="ring", algo="kgt_minimax",
           eta_cx=0.01, eta_cy=0.1, eta_s=0.5, mixing_impl="dense"):
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, n, dx=10, dy=5, heterogeneity=heterogeneity)
    prob = quadratic_problem(data, sigma=sigma)
    cfg = AlgorithmConfig(algorithm=algo, num_clients=n, local_steps=K,
                          eta_cx=eta_cx, eta_cy=eta_cy, eta_sx=eta_s, eta_sy=eta_s,
                          topology=topology, mixing_impl=mixing_impl)
    client_batch = {k: v for k, v in data.items() if k != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), client_batch)
    st = init_state(prob, cfg, key, init_batch=client_batch,
                    init_keys=jax.random.split(key, n))
    step = jax.jit(make_round_step(prob, cfg))
    return prob, cfg, st, step, kb


def _run(st, step, kb, K, n, rounds, seed=7):
    for t in range(rounds):
        keys = jax.random.split(jax.random.PRNGKey(seed + t), K * n).reshape(K, n, 2)
        st = step(st, kb, keys)
    return st


def test_correction_mean_stays_zero():
    """Lemma 8: the averaged correction is exactly zero in every round."""
    prob, cfg, st, step, kb = _setup(sigma=0.3)
    for t in range(10):
        keys = jax.random.split(jax.random.PRNGKey(t), 4 * 8).reshape(4, 8, 2)
        st = step(st, kb, keys)
        mean_c = jax.tree.leaves(jax.tree.map(lambda c: c.mean(0), st.cx))[0]
        assert float(jnp.abs(mean_c).max()) < 1e-4


def test_converges_on_heterogeneous_ncsc():
    prob, cfg, st, step, kb = _setup(sigma=0.1, heterogeneity=2.0)
    st = _run(st, step, kb, 4, 8, 300)
    d = diagnostics(prob, st)
    assert float(d["phi_grad_norm"]) < 0.15
    assert float(d["consensus_x"]) < 1e-3
    # Lemma 8 watchdogs for BOTH corrections (cy reported since PR 3)
    assert float(d["correction_mean_norm"]) < 1e-3
    assert float(d["correction_mean_norm_y"]) < 1e-3


def test_fully_connected_k1_equals_centralized_sgda():
    """With W = J and K = 1 the average iterate follows centralized SGDA
    exactly (deterministic oracle)."""
    n, K = 4, 1
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, n, dx=6, dy=3)
    prob = quadratic_problem(data, sigma=0.0)
    eta_x, eta_y, eta_s = 0.02, 0.1, 1.0
    cfg = AlgorithmConfig(algorithm="kgt_minimax", num_clients=n, local_steps=K,
                          eta_cx=eta_x, eta_cy=eta_y, eta_sx=eta_s, eta_sy=eta_s,
                          topology="full")
    client_batch = {k: v for k, v in data.items() if k != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), client_batch)
    st = init_state(prob, cfg, key, init_batch=client_batch,
                    init_keys=jax.random.split(key, n))
    step = jax.jit(make_round_step(prob, cfg))

    x_c = mean_over_clients(st.x)
    y_c = mean_over_clients(st.y)
    for t in range(20):
        keys = jax.random.split(jax.random.PRNGKey(t), K * n).reshape(K, n, 2)
        st = step(st, kb, keys)
        gx, gy = prob.full_grads(x_c, y_c)
        x_c = x_c - eta_x * gx
        y_c = y_c + eta_y * gy
    np.testing.assert_allclose(mean_over_clients(st.x), x_c, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(mean_over_clients(st.y), y_c, rtol=1e-4, atol=1e-5)


def test_tracking_beats_local_sgda_under_heterogeneity():
    """V3: with strong heterogeneity and local steps, gradient tracking reaches
    a far more stationary point than plain local SGDA at equal budgets."""
    res = {}
    for algo in ("kgt_minimax", "local_sgda"):
        prob, cfg, st, step, kb = _setup(
            sigma=0.0, heterogeneity=3.0, algo=algo, K=8,
            eta_cx=0.01, eta_cy=0.1, eta_s=0.5 if algo == "kgt_minimax" else 1.0)
        st = _run(st, step, kb, 8, 8, 200)
        res[algo] = float(diagnostics(prob, st)["phi_grad_norm"])
    assert res["kgt_minimax"] < 0.15
    assert res["kgt_minimax"] < 0.05 * res["local_sgda"]


@pytest.mark.parametrize("algo", ["dsgda", "local_sgda", "gt_gda"])
def test_baselines_run_and_stay_finite(algo):
    prob, cfg, st, step, kb = _setup(algo=algo, sigma=0.1, eta_cx=0.005,
                                     eta_cy=0.05, K=4)
    st = _run(st, step, kb, 4, 8, 50)
    for leaf in jax.tree.leaves(st.x):
        assert bool(jnp.isfinite(leaf).all())


def test_ring_impl_matches_dense_trajectory():
    """The ppermute-style (roll) gossip is numerically the same algorithm."""
    outs = []
    for impl in ("dense", "ring"):
        prob, cfg, st, step, kb = _setup(sigma=0.0, mixing_impl=impl)
        st = _run(st, step, kb, 4, 8, 30)
        outs.append(np.asarray(mean_over_clients(st.x)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)


def test_fused_pack_is_bit_identical_to_two_gossips():
    """Regression for the claim in kgt_minimax.py: the fused_* variants pack
    both gossips into one collective per leaf with *bit-identical* results —
    stacking (Δ, base) along a new axis must not change the contraction."""
    outs = {}
    for impl in ("dense", "fused_dense"):
        prob, cfg, st, step, kb = _setup(sigma=0.3, mixing_impl=impl)
        outs[impl] = _run(st, step, kb, 4, 8, 10)
    for name in ("x", "y", "cx", "cy"):
        for a, b in zip(jax.tree.leaves(getattr(outs["dense"], name)),
                        jax.tree.leaves(getattr(outs["fused_dense"], name))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)


def doubly_stochastic_w(n: int, seed: int) -> np.ndarray:
    """Random symmetric doubly-stochastic W (symmetrized Sinkhorn), beyond
    the named topologies.  Shared with the hypothesis suite in
    test_property.py."""
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.1, 1.0, (n, n))
    a = a + a.T + n * np.eye(n)
    for _ in range(200):
        a = a / a.sum(1, keepdims=True)
        a = (a + a.T) / 2
    assert np.allclose(a.sum(1), 1.0, atol=1e-9) and np.allclose(a, a.T)
    return a


def check_round_mean_dynamics(algo, n, k, seed, mixing_impl="dense"):
    """One round_step under any doubly-stochastic W: the client mean of x/y
    evolves exactly as under W = J (mixing preserves the mean), and Lemma 8's
    Σ_i c_i = 0 invariant holds."""
    w = doubly_stochastic_w(n, seed)
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=5, dy=3, heterogeneity=2.0)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(algorithm=algo, num_clients=n, local_steps=k,
                          eta_cx=0.01, eta_cy=0.05, eta_sx=0.4, eta_sy=0.4,
                          mixing_impl=mixing_impl, gossip_backend="xla")
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    st = init_state(prob, cfg, key, init_batch=cb,
                    init_keys=jax.random.split(key, n))
    step_w = make_round_step(prob, cfg, w)
    step_j = make_round_step(prob, cfg, np.full((n, n), 1.0 / n))
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), k * n).reshape(k, n, 2)
    st_w = step_w(st, kb, keys)
    st_j = step_j(st, kb, keys)
    np.testing.assert_allclose(mean_over_clients(st_w.x),
                               mean_over_clients(st_j.x),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(mean_over_clients(st_w.y),
                               mean_over_clients(st_j.y),
                               rtol=1e-5, atol=1e-5)
    for c in (st_w.cx, st_w.cy):
        mean_c = jax.tree.leaves(jax.tree.map(lambda v: v.mean(0), c))[0]
        assert float(jnp.abs(mean_c).max()) < 1e-4


@pytest.mark.parametrize("algo", ["kgt_minimax", "dsgda", "local_sgda", "gt_gda"])
@pytest.mark.parametrize("mixing_impl", ["dense", "pallas_packed",
                                         "sparse_packed"])
def test_round_mean_dynamics_under_random_doubly_stochastic_w(algo, mixing_impl):
    """Deterministic cousin of the hypothesis property in test_property.py
    (which runs everywhere since the bundled fallback landed)."""
    check_round_mean_dynamics(algo, n=6, k=3, seed=11, mixing_impl=mixing_impl)


def check_participation_invariants(algo, n, k, seed, mask_bits,
                                   mixing_impl="dense", rounds=2):
    """Round steps with traced W + a participation mask (mask_bits: client i
    active iff bit i set): the client-mean dynamics are W-independent (the
    masked W stays doubly stochastic, so x̄ moves by η_s·mean(masked Δ)
    whatever W was drawn), Σ_i c_i stays 0 under ANY mask, and inactive
    clients' (θ, c) are frozen bit-exactly."""
    from repro.core import sparse_topology as sparse
    from repro.core import stochastic_topology as stoch

    mask = jnp.asarray([(mask_bits >> i) & 1 == 1 for i in range(n)])
    w = doubly_stochastic_w(n, seed)
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=5, dy=3, heterogeneity=2.0)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(algorithm=algo, num_clients=n, local_steps=k,
                          eta_cx=0.01, eta_cy=0.05, eta_sx=0.4, eta_sy=0.4,
                          mixing_impl=mixing_impl, gossip_backend="xla")
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    st = init_state(prob, cfg, key, init_batch=cb,
                    init_keys=jax.random.split(key, n))
    step = jax.jit(make_round_step(prob, cfg, traced_w=True,
                                   participation=True))
    # the sparse_packed traced-W operand is a SparseTopology pytree; the
    # dense Ws here are fully connected, so from_dense keeps every edge
    bridge = (sparse.from_dense if mixing_impl == "sparse_packed"
              else lambda a: jnp.asarray(a, jnp.float32))
    w_t = bridge(np.asarray(w, np.float32))
    w_j = bridge(np.full((n, n), 1.0 / n, np.float32))
    st_w = st
    inactive = ~np.asarray(mask)
    for t in range(rounds):
        keys = jax.random.split(jax.random.PRNGKey(seed + t),
                                k * n).reshape(k, n, 2)
        prev_w = st_w
        st_w = step(st_w, kb, keys, w_t, mask)
        if t == 0:
            # W-independence of the mean is a ONE-round property from a
            # common state (after a round the per-client spread differs, so
            # later local gradients do too): x̄ must move exactly as under
            # W = J masked by the same participation pattern
            st_j = step(prev_w, kb, keys, w_j, mask)
            np.testing.assert_allclose(mean_over_clients(st_w.x),
                                       mean_over_clients(st_j.x),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(mean_over_clients(st_w.y),
                                       mean_over_clients(st_j.y),
                                       rtol=1e-5, atol=1e-5)
        # inactive clients frozen bit-exactly, every round
        for name in ("x", "y", "cx", "cy"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st_w, name))[inactive],
                np.asarray(getattr(prev_w, name))[inactive], err_msg=name)
        for c in (st_w.cx, st_w.cy):
            mean_c = jax.tree.leaves(jax.tree.map(lambda v: v.mean(0), c))[0]
            assert float(jnp.abs(mean_c).max()) < 1e-4


@pytest.mark.parametrize("algo", ["kgt_minimax", "dsgda", "local_sgda", "gt_gda"])
@pytest.mark.parametrize("mixing_impl", ["dense", "pallas_packed",
                                         "sparse_packed"])
def test_participation_invariants_all_variants(algo, mixing_impl):
    """Deterministic cousin of the participation hypothesis properties in
    test_property.py: a mask dropping clients 1 and 3 of 6."""
    check_participation_invariants(algo, n=6, k=3, seed=5,
                                   mask_bits=0b110101, mixing_impl=mixing_impl)


def test_participation_all_inactive_freezes_everything():
    """The degenerate all-clients-down round is a global no-op (bit-exact),
    except the round counter advances."""
    n, k = 4, 2
    key = jax.random.PRNGKey(3)
    data = make_quadratic_data(key, n, dx=4, dy=2)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(num_clients=n, local_steps=k, eta_cx=0.01,
                          eta_cy=0.05)
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    st = init_state(prob, cfg, key, init_batch=cb,
                    init_keys=jax.random.split(key, n))
    step = jax.jit(make_round_step(prob, cfg, participation=True))
    keys = jax.random.split(jax.random.PRNGKey(0), k * n).reshape(k, n, 2)
    out = step(st, kb, keys, jnp.zeros((n,), bool))
    for name in ("x", "y", "cx", "cy"):
        for a, b in zip(jax.tree.leaves(getattr(out, name)),
                        jax.tree.leaves(getattr(st, name))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(out.round) == int(st.round) + 1


def test_round_step_extras_arity_validated():
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, 4, dx=4, dy=2)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(num_clients=4, local_steps=2)
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (2, *v.shape)), cb)
    st = init_state(prob, cfg, key)
    step = make_round_step(prob, cfg, traced_w=True)
    keys = jax.random.split(key, 2 * 4).reshape(2, 4, 2)
    with pytest.raises(TypeError, match="extra operand"):
        step(st, kb, keys)  # missing the traced W


def test_make_round_step_validates_mixing_impl():
    """The impl/topology pairing is validated on BOTH branches — including
    topology_cycle, which lowers gossip densely and ignores make_mixer."""
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, 4, dx=4, dy=2)
    prob = quadratic_problem(data, sigma=0.0)
    for cfg in (
        AlgorithmConfig(num_clients=4, mixing_impl="bogus"),
        AlgorithmConfig(num_clients=4, mixing_impl="bogus",
                        topology_cycle=("ring", "full")),
        AlgorithmConfig(num_clients=4, mixing_impl="ring",
                        topology_cycle=("ring", "full")),
        AlgorithmConfig(num_clients=4, mixing_impl="fused_ring",
                        topology="exp"),
    ):
        with pytest.raises(ValueError):
            make_round_step(prob, cfg)
    # the churn paths lower gossip densely: ring impls can't realize a
    # traced/masked W, and traced_w fights a topology_cycle
    with pytest.raises(ValueError, match="neighbor-only"):
        make_round_step(prob, AlgorithmConfig(num_clients=4,
                                              mixing_impl="ring"),
                        traced_w=True)
    with pytest.raises(ValueError, match="neighbor-only"):
        make_round_step(prob, AlgorithmConfig(num_clients=4,
                                              mixing_impl="fused_ring"),
                        participation=True)
    with pytest.raises(ValueError, match="topology_cycle"):
        make_round_step(prob, AlgorithmConfig(num_clients=4,
                                              topology_cycle=("ring", "full")),
                        traced_w=True)


def test_consensus_reached_from_identical_init():
    prob, cfg, st, step, kb = _setup(sigma=0.0, heterogeneity=0.0)
    st = _run(st, step, kb, 4, 8, 100)
    d = diagnostics(prob, st)
    assert float(d["consensus_x"]) < 1e-5
