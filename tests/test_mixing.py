import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mixing, topology


def _tree(n, key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (n, 5)),
            "b": {"c": jax.random.normal(k2, (n, 3, 2))}}


@pytest.mark.parametrize("n", [2, 3, 4, 8])
def test_ring_matches_dense(n):
    w = topology.mixing_matrix("ring", n)
    tree = _tree(n, jax.random.PRNGKey(0))
    dense = mixing.mix_dense(tree, w)
    ring = mixing.mix_ring(tree, float(w[0, 0]), float(w[0, 1 % n]))
    for d, r in zip(jax.tree.leaves(dense), jax.tree.leaves(ring)):
        np.testing.assert_allclose(d, r, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name", ["ring", "full", "exp"])
def test_mixing_preserves_mean(name):
    n = 8
    w = topology.mixing_matrix(name, n)
    tree = _tree(n, jax.random.PRNGKey(1))
    mixed = mixing.mix_dense(tree, w)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(mixed)):
        np.testing.assert_allclose(a.mean(0), b.mean(0), rtol=1e-5, atol=1e-6)


def test_mixing_contracts_consensus_error():
    n = 8
    w = topology.mixing_matrix("ring", n)
    tree = _tree(n, jax.random.PRNGKey(2))
    e0 = float(mixing.consensus_error(tree))
    e1 = float(mixing.consensus_error(mixing.mix_dense(tree, w)))
    p = topology.spectral_gap(w)
    assert e1 <= (1 - p) * e0 + 1e-6


def test_bf16_gossip_close_to_f32():
    n = 4
    w = topology.mixing_matrix("ring", n)
    tree = _tree(n, jax.random.PRNGKey(3))
    exact = mixing.mix_dense(tree, w)
    approx = mixing.mix_dense(tree, w, gossip_dtype=jnp.bfloat16)
    for a, b in zip(jax.tree.leaves(exact), jax.tree.leaves(approx)):
        np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)


def test_make_mixer_dispatch():
    w = topology.mixing_matrix("ring", 4)
    tree = _tree(4, jax.random.PRNGKey(4))
    for impl in ("dense", "ring", "fused_ring", "pallas_packed"):
        out = mixing.make_mixer("ring", impl, w)(tree)
        np.testing.assert_allclose(
            jax.tree.leaves(out)[0], jax.tree.leaves(mixing.mix_dense(tree, w))[0],
            rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("impl", ["ring", "fused_ring"])
@pytest.mark.parametrize("topo", ["full", "exp", "torus", "star"])
def test_make_mixer_rejects_ring_impl_on_non_ring_topology(impl, topo):
    """Previously this silently fell back to dense — wrong impl, right
    numbers — masking a misconfiguration.  Now it raises."""
    n = 4
    w = topology.mixing_matrix(topo, n)
    with pytest.raises(ValueError, match="ring"):
        mixing.make_mixer(topo, impl, w)


def test_make_mixer_rejects_unknown_impl():
    w = topology.mixing_matrix("ring", 4)
    with pytest.raises(ValueError, match="unknown mixing_impl"):
        mixing.make_mixer("ring", "bogus", w)


def test_mix_packed_matches_per_leaf_dense():
    n = 8
    w = topology.mixing_matrix("exp", n)
    tree = _tree(n, jax.random.PRNGKey(5))
    packed = mixing.mix_packed(tree, w)
    dense = mixing.mix_dense(tree, w)
    for a, b in zip(jax.tree.leaves(packed), jax.tree.leaves(dense)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
