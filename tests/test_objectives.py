import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_model_config, reduced
from repro.core import objectives


def test_quadratic_phi_grad_matches_autodiff():
    """∇Φ(x) = ∇_x f(x, y*(x)) by Danskin — verify the closed form against
    autodiff through the inner argmax solution."""
    key = jax.random.PRNGKey(0)
    n = 6
    data = objectives.make_quadratic_data(key, n, dx=8, dy=4, mu=2.0)
    prob = objectives.quadratic_problem(data, sigma=0.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (8,))

    a_bar = data["A"].mean(0)
    b_bar = data["B"].mean(0)
    bv_bar = data["b"].mean(0)
    q_bar = data["q"].mean(0)

    def phi(x):
        ystar = (b_bar @ x + bv_bar) / 2.0
        return 0.5 * x @ (a_bar @ x) + q_bar @ x + ystar @ (b_bar @ x) \
            + bv_bar @ ystar - 1.0 * ystar @ ystar
    np.testing.assert_allclose(prob.phi_grad(x), jax.grad(phi)(x), rtol=1e-4,
                               atol=1e-5)


def test_quadratic_grads_unbiased():
    """Assumption 3: stochastic grads average to the deterministic ones."""
    key = jax.random.PRNGKey(0)
    data = objectives.make_quadratic_data(key, 4, dx=6, dy=3)
    prob = objectives.quadratic_problem(data, sigma=0.5)
    x = jnp.ones((6,))
    y = jnp.ones((3,))
    batch = jax.tree.map(lambda v: v[0], {k: v for k, v in data.items() if k != "mu"})
    gxs, gys = [], []
    for i in range(500):
        gx, gy = prob.grads(x, y, batch, jax.random.PRNGKey(i))
        gxs.append(gx)
        gys.append(gy)
    prob0 = objectives.quadratic_problem(data, sigma=0.0)
    gx0, gy0 = prob0.grads(x, y, batch, jax.random.PRNGKey(0))
    np.testing.assert_allclose(jnp.stack(gxs).mean(0), gx0, atol=0.1)
    np.testing.assert_allclose(jnp.stack(gys).mean(0), gy0, atol=0.1)


def test_dro_value_strongly_concave_in_y():
    cfg = reduced(get_model_config("qwen2-0.5b"))
    prob = objectives.dro_problem(cfg, num_groups=4, mu=2.0)
    key = jax.random.PRNGKey(0)
    x = prob.init_x(key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks,
             "groups": jnp.zeros((2, 16), jnp.int32)}
    # f(x, .) has Hessian -mu*I exactly (linear + quadratic penalty)
    h = jax.hessian(lambda y: prob.value(x, y, batch, None))(jnp.ones(4))
    np.testing.assert_allclose(h, -2.0 * jnp.eye(4), atol=1e-3)


def test_adversarial_value_finite_and_grad_flows():
    cfg = reduced(get_model_config("qwen2-0.5b"))
    prob = objectives.adversarial_problem(cfg, mu=10.0, scale=0.1)
    key = jax.random.PRNGKey(0)
    x = prob.init_x(key)
    y = prob.init_y(key) + 0.1
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    gx, gy = prob.grads(x, y, batch, None)
    assert bool(jnp.isfinite(gy).all())
    assert float(jnp.abs(gy).sum()) > 0  # perturbation actually affects loss
