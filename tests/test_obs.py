"""Tests of the observability subsystem (repro.obs).

The load-bearing claims: telemetry off is *zero-overhead* (bit-identical
trajectories, no extra dispatches — the engine's ``telemetry=None`` path is
the original code path); the communication ledger's analytic bytes/round
match hand-computed wire arithmetic for every lowering family and separate
the lowerings in the expected ratios; and a JSONL artifact round-trips
through ``repro.obs.report`` for every event type.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as engine_lib
from repro import obs
from repro.configs.base import AlgorithmConfig
from repro.core import (
    init_state,
    make_quadratic_data,
    make_round_step,
    quadratic_problem,
)
from repro.obs import report


def _setup(algo="kgt_minimax", mixing_impl="dense", n=4, K=3, sigma=0.3,
           seed=0, gossip_compress=None):
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=6, dy=3, heterogeneity=1.5)
    prob = quadratic_problem(data, sigma=sigma)
    cfg = AlgorithmConfig(
        algorithm=algo, num_clients=n, local_steps=K, eta_cx=0.01,
        eta_cy=0.1, eta_sx=0.5, eta_sy=0.5, topology="ring",
        mixing_impl=mixing_impl, gossip_backend="xla",
        gossip_compress=gossip_compress)
    cb = {k: v for k, v in data.items() if k != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (K, *v.shape)), cb)
    st = init_state(prob, cfg, key, init_batch=cb,
                    init_keys=jax.random.split(key, n))
    step = make_round_step(prob, cfg)
    sampler = engine_lib.make_fixed_batch_sampler(
        kb, local_steps=K, num_clients=n, seed=seed)
    return prob, cfg, st, step, sampler


def _assert_states_equal(a, b, context=""):
    for name in ("x", "y", "cx", "cy"):
        for la, lb in zip(jax.tree.leaves(getattr(a, name)),
                          jax.tree.leaves(getattr(b, name))):
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb), err_msg=f"{context}:{name}")
    assert int(a.round) == int(b.round)


# ---------------------------------------------------------------- events


def test_disabled_telemetry_is_noop():
    """A sink-less Telemetry must never touch the clock or build objects:
    span() returns the shared null context manager, emit/metrics return
    before stamping."""
    tel = obs.Telemetry(())
    assert not tel.enabled
    s1, s2 = tel.span("dispatch"), tel.span("readback", round=3)
    assert s1 is s2  # the shared _NULL_SPAN, not a fresh object
    with s1:
        pass
    tel.metrics({"round": 0})
    tel.counter("bytes", 10)
    tel.gauge("g", 1.0)
    tel.close()
    assert obs.NULL.span("x") is s1


def test_telemetry_stamps_and_fans_out():
    a, b = obs.MemorySink(), obs.MemorySink()
    tel = obs.Telemetry([a, b])
    with tel.span("dispatch", round=2, length=4):
        pass
    tel.counter("rounds", 4)
    tel.gauge("consensus_x", 0.5, round=4)
    tel.metrics({"round": 3, "f_bar": 1.25})
    tel.meta("run", arch="toy")
    assert len(a.events) == len(b.events) == 5
    for ev in a.events:
        assert ev["v"] == obs.TELEMETRY_VERSION
        assert ev["type"] in ("span", "counter", "gauge", "metrics", "meta")
        assert "t" in ev
    span = a.events[0]
    assert span["name"] == "dispatch" and span["dur_s"] >= 0
    assert span["round"] == 2 and span["length"] == 4
    assert a.events[3]["f_bar"] == 1.25


def test_stderr_sink_formatter_filters(capsys):
    """formatter -> None drops the event from the console entirely."""
    sink = obs.StderrSink(lambda ev: f"row {ev['round']}"
                          if ev["type"] == "metrics" else None)
    tel = obs.Telemetry([sink])
    tel.metrics({"round": 7})
    tel.gauge("hidden", 1.0)
    err = capsys.readouterr().err
    assert "row 7" in err and "hidden" not in err


# ------------------------------------------------------- zero overhead


def test_engine_bit_identical_with_telemetry_on():
    """The hard guarantee: running the engine with a full telemetry stack
    (spans + metrics/ledger/health hook) produces the bit-identical state
    and history to the plain telemetry=None run."""
    prob, cfg, st, step, sampler = _setup()
    build = engine_lib.make_chunk_builder(
        step, sampler, engine_lib.quadratic_metrics_fn(prob), log_every=2,
        donate=False)
    st_plain, hist_plain = engine_lib.run(
        st, build, total_rounds=10, chunk_rounds=4, wall_clock=False)

    sink = obs.MemorySink()
    tel = obs.Telemetry([sink])
    ledger = obs.ledger_for_state(cfg, st)
    hook = engine_lib.telemetry_hook(tel, ledger=ledger,
                                     health_fn=obs.health_gauges)
    st_tel, hist_tel = engine_lib.run(
        st, build, total_rounds=10, chunk_rounds=4, wall_clock=False,
        hooks=[hook], telemetry=tel)

    _assert_states_equal(st_plain, st_tel, "telemetry on/off")
    assert hist_plain == hist_tel
    # and the stream actually recorded the run
    types = {ev["type"] for ev in sink.events}
    assert {"span", "metrics", "ledger", "gauge"} <= types
    assert ledger.rounds == 10


def test_telemetry_hook_emits_per_boundary():
    sink = obs.MemorySink()
    tel = obs.Telemetry([sink])
    comm = obs.round_comm(mixing_impl="dense", n=4, dims=(6, 3))
    ledger = obs.CommLedger(comm)
    calls = []

    def health(state):
        calls.append(int(state.round))
        return {"corr_x_drift": 0.0}

    hook = engine_lib.telemetry_hook(tel, ledger=ledger, health_fn=health,
                                     health_every=2)

    class S:
        def __init__(self, r):
            self.round = jnp.int32(r)

    hook(S(4), [{"round": 1}, {"round": 3}], 0)
    hook(S(8), [{"round": 5}], 4)
    hook(S(12), [], 8)
    metrics = [e for e in sink.events if e["type"] == "metrics"]
    ledgers = [e for e in sink.events if e["type"] == "ledger"]
    gauges = [e for e in sink.events if e["type"] == "gauge"]
    assert [m["round"] for m in metrics] == [1, 3, 5]
    assert [l["rounds"] for l in ledgers] == [4, 4, 4]
    assert ledgers[-1]["rounds_total"] == 12
    assert ledgers[-1]["bytes_total"] == 12 * comm.bytes_per_round
    # health_every=2: boundaries 0 and 2 sample, boundary 1 skips
    assert calls == [4, 12]
    assert all(g["name"] == "corr_x_drift" for g in gauges)


# ------------------------------------------------------------- ledger


def test_ledger_dense_hand_computed():
    """n=8, dims (10, 5), f32, tracking: every client receives from the
    other 7 -> 56 links, two gossiped quantities (Δ and θ) of 15 elements
    at 4 bytes."""
    c = obs.round_comm(mixing_impl="dense", n=8, dims=(10, 5))
    assert c.links == 8 * 7
    assert c.quantities == 2
    assert c.bytes_per_round == 56 * 15 * 4 * 2 == 6720
    assert c.collectives_per_round == 4        # 2 per leaf x (1, 1) leaves


def test_ledger_separates_lowerings_in_expected_ratios():
    """The acceptance criterion: dense vs sparse_packed vs
    fused_round+int8 differ in analytically expected ratios."""
    n, dims = 8, (10, 5)
    dense = obs.round_comm(mixing_impl="dense", n=n, dims=dims)
    sparse = obs.round_comm(mixing_impl="sparse_packed", n=n, dims=dims,
                            topology="ring")
    fused8 = obs.round_comm(mixing_impl="fused_round", n=n, dims=dims,
                            gossip_compress="int8")

    # sparse ring support: 2 neighbors/client -> 16 directed edges; the
    # bytes ratio vs all-gather dense is exactly (n-1)/deg = 7/2
    assert sparse.links == 2 * n
    assert dense.bytes_per_round / sparse.bytes_per_round == (n - 1) / 2
    assert sparse.bytes_per_round == 16 * 15 * 4 * 2 == 1920

    # int8 narrows the Δ-gossip to 1 B/elem + one f32 scale per variable
    # per link; θ stays f32
    theta = 56 * 15 * 4
    delta = 56 * (15 * 1 + 4 * 2)
    assert fused8.bytes_per_round == theta + delta == 4648
    assert fused8.bytes_per_round / dense.bytes_per_round == pytest.approx(
        (theta + delta) / 6720)

    # three distinct lowerings -> three distinct bytes/round
    assert len({dense.bytes_per_round, sparse.bytes_per_round,
                fused8.bytes_per_round}) == 3
    # and the collective-launch progression of the gossip bench: 4 -> 2 -> 1
    assert dense.collectives_per_round == 4
    assert sparse.collectives_per_round == 2
    assert fused8.collectives_per_round == 1


def test_ledger_ring_and_edge_cases():
    ring = obs.round_comm(mixing_impl="ring", n=8, dims=(10, 5))
    assert ring.links == 16
    assert ring.bytes_per_round == 16 * 15 * 4 * 2
    assert obs.links_per_gossip("ring", 2) == 2    # one neighbor each
    assert obs.links_per_gossip("ring", 1) == 0
    # bf16 compression: 2 B/elem on the Δ wire, no row scale
    bf = obs.round_comm(mixing_impl="pallas_packed", n=8, dims=(10, 5),
                        gossip_compress="bf16")
    assert bf.bytes_per_round == 56 * 15 * 4 + 56 * 15 * 2
    # no tracking on a packed lowering: single pre-stepped gossip
    nt = obs.round_comm(mixing_impl="pallas_packed", n=8, dims=(10, 5),
                        track=False)
    assert nt.quantities == 1
    assert nt.bytes_per_round == 56 * 15 * 4
    with pytest.raises(ValueError):
        obs.round_comm(mixing_impl="nope", n=8, dims=(10, 5))
    with pytest.raises(ValueError):
        obs.round_comm(mixing_impl="dense", n=8, dims=(10, 5),
                       gossip_compress="int3")


def test_ledger_for_state_reads_packed_dims():
    """ledger_for_state derives (D_x, D_y) from the live state's pack
    specs — the quadratic state is (n, 6) + (n, 3)."""
    prob, cfg, st, step, sampler = _setup(n=4)
    ledger = obs.ledger_for_state(cfg, st)
    assert ledger.comm.dims == (6, 3)
    assert ledger.comm.links == 4 * 3
    assert ledger.bytes_per_round == 12 * 9 * 4 * 2
    ledger.add_rounds(5)
    ev = ledger.event(rounds=5)
    assert ev["bytes_total"] == 5 * ledger.bytes_per_round
    assert ev["bytes"] == ev["bytes_total"]
    assert ev["type"] == "ledger"


def test_ledger_no_tracking_baseline_state():
    """local_sgda carries no corrections: packed lowerings collapse to one
    gossiped quantity."""
    prob, cfg, st, step, sampler = _setup(algo="local_sgda",
                                          mixing_impl="pallas_packed")
    ledger = obs.ledger_for_state(cfg, st)
    assert ledger.comm.quantities == 1


def test_sweep_cell_comm_matches_ledger():
    """sweep.run.cell_comm prices a cell point on the sweep geometry
    (DX=10, DY=5) with the point's own statics."""
    from repro.sweep import run as sweep_run

    c = sweep_run.cell_comm({"mixing_impl": "dense"})
    assert c.bytes_per_round == obs.round_comm(
        mixing_impl="dense", n=8, dims=(10, 5)).bytes_per_round
    c2 = sweep_run.cell_comm({"mixing_impl": "sparse_packed",
                              "algorithm": "local_sgda"})
    assert c2.quantities == 1


# ---------------------------------------------------- report round-trip


def test_jsonl_roundtrip_every_event_type(tmp_path):
    """Write one of every event type through the JsonlSink, fold it back
    through report.load + summarize."""
    path = str(tmp_path / "run.jsonl")
    tel = obs.Telemetry([obs.JsonlSink(path)])
    tel.meta("train", arch="toy", n=4)
    tel.span_event("compile", 1.5, round=0)
    with tel.span("dispatch", round=0, length=4):
        pass
    tel.counter("chunks", 1)
    tel.gauge("consensus_x", 0.25, round=4)
    tel.metrics({"round": 0, "phi_grad_norm": 2.0, "wall_s": 0.5})
    tel.metrics({"round": 4, "phi_grad_norm": 1.0, "wall_s": 1.0,
                 "run_s": 0.8, "compile_s": 1.5})
    ledger = obs.CommLedger(obs.round_comm(mixing_impl="dense", n=4,
                                           dims=(6, 3)))
    ledger.add_rounds(5)
    tel.emit(ledger.event(rounds=5))
    tel.close()

    events = report.load(path)
    assert {e["type"] for e in events} == set(obs.EVENT_TYPES)
    # jax scalars went through the float() fallback -> plain JSON numbers
    assert all(isinstance(e["t"], float) for e in events)
    s = report.summarize(events)
    assert s["num_events"] == 8
    assert s["spans"]["compile"] == {"count": 1, "total_s": 1.5}
    assert s["spans"]["dispatch"]["count"] == 1
    assert s["counters"]["chunks"] == {"count": 1, "sum": 1.0}
    assert s["gauges"]["consensus_x"] == 0.25
    assert s["meta"]["arch"] == "toy"
    assert s["rounds"] == 5 and s["num_metric_rows"] == 2
    assert s["rounds_per_s"] == pytest.approx(5 / 0.8, abs=1e-3)
    assert s["tail"] == {"phi_grad_norm": 1.0}
    assert s["ledger"]["bytes_per_round"] == ledger.bytes_per_round
    assert s["ledger"]["bytes_total"] == 5 * ledger.bytes_per_round
    rendered = report.render(s)
    assert "time breakdown" in rendered and "communication [dense]" in rendered


def test_jsonl_sink_never_raises_on_exotic_values(tmp_path):
    path = str(tmp_path / "run.jsonl")
    tel = obs.Telemetry([obs.JsonlSink(path)])
    tel.metrics({"round": 0, "f_bar": jnp.float32(1.5),
                 "arr": np.arange(2), "obj": object()})
    tel.close()
    (ev,) = report.load(path)
    assert ev["f_bar"] == 1.5


def test_report_cli_fails_on_bad_artifacts(tmp_path, capsys):
    missing = str(tmp_path / "nope.jsonl")
    assert report.main([missing]) == 1

    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert report.main([str(empty)]) == 1

    malformed = tmp_path / "bad.jsonl"
    malformed.write_text('{"type": "meta"}\n{broken\n')
    assert report.main([str(malformed)]) == 1
    assert "bad.jsonl:2" in capsys.readouterr().err

    untyped = tmp_path / "untyped.jsonl"
    untyped.write_text('{"no_type": 1}\n')
    assert report.main([str(untyped)]) == 1

    good = tmp_path / "good.jsonl"
    good.write_text(json.dumps({"type": "meta", "arch": "toy"}) + "\n")
    assert report.main([str(good)]) == 0
    assert report.main([str(good), "--json"]) == 0


# ----------------------------------------------------------- profiler


def test_profiler_window_closes_after_n_rounds():
    class Prof(obs.Profiler):
        def __init__(self, *a, **k):
            super().__init__(*a, **k)
            self.stopped = 0

        def stop(self):
            self.stopped += 1
            self.active = False

    class S:
        def __init__(self, r):
            self.round = jnp.int32(r)

    prof = Prof("/tmp/unused", num_rounds=6)
    prof.active = True  # as if start_trace succeeded
    prof.hook(S(4), [], 0)     # window = rounds [0, 6)
    assert prof.active and prof.stopped == 0
    prof.hook(S(8), [], 4)
    assert prof.stopped == 1 and not prof.active
    prof.hook(S(12), [], 8)    # closed window: no double stop
    assert prof.stopped == 1

    whole = Prof("/tmp/unused", num_rounds=0)
    whole.active = True
    whole.hook(S(100), [], 96)  # 0 = whole run, only stop() closes it
    assert whole.active and whole.stopped == 0


def test_health_gauges_values():
    prob, cfg, st, step, sampler = _setup()
    g = obs.health_gauges(st)
    # tracking corrections start mean-zero by construction (Lemma 8), and
    # all clients share x0/y0 so consensus starts at 0
    assert g["corr_x_drift"] == pytest.approx(0.0, abs=1e-5)
    assert g["corr_y_drift"] == pytest.approx(0.0, abs=1e-5)
    assert g["consensus_x"] == pytest.approx(0.0, abs=1e-6)
    assert "ef_x_norm" not in g  # no compression -> no EF residuals
    for v in g.values():
        assert isinstance(v, float) and math.isfinite(v)


# -------------------------------------------------- train-driver wiring


def test_format_record_handles_sparse_schemas():
    """Satellite fix: _print_record used to KeyError on metric rows that
    lack f_bar/mean_loss/consensus_x (e.g. quadratic_metrics_fn rows)."""
    from repro.launch import train as train_lib

    quad_row = {"round": 3, "phi_grad_norm": 0.125, "wall_s": 1.5}
    line = train_lib._format_record(quad_row)
    assert "round    3" in line and "‖∇Φ‖=0.1250" in line

    dro_row = {"round": 2, "f_bar": 1.0, "mean_loss": 2.0, "eval_loss": 3.0,
               "consensus_x": 1e-4, "y_bar_norm": 0.5, "wall_s": 2.0}
    line = train_lib._format_record(dro_row)
    for frag in ("f(x̄,ȳ)=1.0000", "ℓ̄=2.0000", "Ξx=1.000e-04"):
        assert frag in line

    train_lib._print_record({"round": 0})  # must not raise on minimal rows
    assert train_lib._stderr_event_format({"type": "gauge"}) is None
    assert "‖∇Φ‖" in train_lib._stderr_event_format(
        {"type": "metrics", "v": 1, "t": 0.0, **quad_row})


def test_train_telemetry_artifact_and_zero_overhead(tmp_path):
    """End-to-end acceptance: --telemetry-out produces a JSONL that
    repro.obs.report folds (meta + spans + metrics + ledger + gauges), the
    ledger block matches the analytic model for the run's lowering, and
    the logged history is identical to the telemetry-off run."""
    from repro.launch import train as train_lib

    def args(**over):
        import argparse

        base = dict(
            arch="qwen2-0.5b", reduced=True, algorithm="kgt_minimax",
            rounds=4, clients=2, local_steps=2, batch=2, seq_len=32,
            groups=4, mu=1.0, alpha=0.3, eta_cx=0.02, eta_cy=0.2,
            eta_s=0.7, topology="ring", mixing_impl="dense",
            gossip_dtype="float32", schedule="constant", warmup=0, seed=0,
            log_every=2, checkpoint_every=0,
            checkpoint_dir=str(tmp_path / "ckpt"), out=None, engine="scan",
            chunk=2)
        base.update(over)
        return argparse.Namespace(**base)

    path = tmp_path / "run.jsonl"
    res_tel = train_lib.train(args(telemetry_out=str(path)))
    res_plain = train_lib.train(args())
    # identical up to the wall-clock stamps, which measure real time
    timing = ("wall_s", "compile_s", "run_s")
    strip = lambda hist: [{k: v for k, v in rec.items() if k not in timing}
                          for rec in hist]  # noqa: E731
    assert strip(res_tel["history"]) == strip(res_plain["history"])

    s = report.summarize(report.load(str(path)))
    assert s["meta"]["arch"].startswith("qwen2-0.5b")  # the reduced variant
    assert "dispatch" in s["spans"] and "compile" in s["spans"]
    assert s["num_metric_rows"] == len(res_tel["history"])
    assert {"corr_x_drift", "consensus_x"} <= set(s["gauges"])
    led = s["ledger"]
    assert led["mixing_impl"] == "dense" and led["rounds"] == 4
    # the analytic model for this run: n=2 dense all-gather
    assert led["bytes_per_round"] % (2 * 1 * 4) == 0
    assert led["bytes_total"] == 4 * led["bytes_per_round"]
    assert report.render(s)
