import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adam, get_schedule, momentum, sgd


@pytest.mark.parametrize("opt_fn", [sgd, momentum, adam])
def test_optimizers_minimize_quadratic(opt_fn):
    opt = opt_fn()
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, 0.05)
    assert float(loss(params)) < 1e-3


def test_wsd_schedule_shape():
    fn = get_schedule("wsd", total_rounds=100, warmup=10)
    vals = [float(fn(t)) for t in range(100)]
    assert vals[0] < 0.2                      # warming up
    assert abs(vals[50] - 1.0) < 1e-6         # stable plateau
    assert vals[99] < 0.2                     # decayed
    assert max(vals) <= 1.0 + 1e-6


def test_cosine_schedule_monotone_decay():
    fn = get_schedule("cosine", total_rounds=50, warmup=0)
    vals = [float(fn(t)) for t in range(50)]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))
    assert vals[-1] >= 0.1 - 1e-6  # floor


def test_constant_schedule():
    fn = get_schedule("constant", total_rounds=10)
    assert float(fn(5)) == 1.0
