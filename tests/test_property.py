"""Hypothesis property tests on system invariants.

This suite RUNS everywhere — 0 skips: with the real ``hypothesis`` when
installed (the ``[dev]`` extra), else on the bundled deterministic fallback
(``repro.testing.minihypothesis``).  ``tests/_hyp.py`` selects; stay within
the strategy subset it implements.  scripts/smoke.sh fails CI if this file
collects zero tests or reports any skip.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.configs.base import AlgorithmConfig
from repro.core import (
    init_state,
    make_quadratic_data,
    make_round_step,
    mean_over_clients,
    mixing_matrix,
    quadratic_problem,
    spectral_gap,
)
from repro.core import stochastic_topology as stoch
from repro.core.mixing import consensus_error, mix_dense
from repro.kernels import rglru_scan


@given(n=st.integers(2, 12), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_dense_mixing_is_linear_and_mean_preserving(n, seed):
    w = mixing_matrix("ring", n)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, 7))
    y = jax.random.normal(jax.random.fold_in(key, 1), (n, 7))
    a = 0.37
    lhs = mix_dense({"t": a * x + y}, w)["t"]
    rhs = a * mix_dense({"t": x}, w)["t"] + mix_dense({"t": y}, w)["t"]
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(lhs.mean(0), (a * x + y).mean(0), rtol=1e-5,
                               atol=1e-5)


@given(n=st.integers(2, 8), k=st.integers(1, 5), het=st.floats(0.0, 3.0),
       sigma=st.floats(0.0, 0.5))
@settings(max_examples=15, deadline=None)
def test_correction_sum_invariant(n, k, het, sigma):
    """Lemma 8 as a property: Σ_i c_i = 0 after arbitrary rounds for any
    (n, K, heterogeneity, noise)."""
    key = jax.random.PRNGKey(n * 31 + k)
    data = make_quadratic_data(key, n, dx=5, dy=3, heterogeneity=het)
    prob = quadratic_problem(data, sigma=sigma)
    cfg = AlgorithmConfig(num_clients=n, local_steps=k, eta_cx=0.01,
                          eta_cy=0.05, eta_sx=0.3, eta_sy=0.3, topology="ring")
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    stt = init_state(prob, cfg, key, init_batch=cb,
                     init_keys=jax.random.split(key, n))
    step = make_round_step(prob, cfg)
    for t in range(3):
        keys = jax.random.split(jax.random.PRNGKey(t), k * n).reshape(k, n, 2)
        stt = step(stt, kb, keys)
    for c in (stt.cx, stt.cy):
        mean_c = jax.tree.leaves(jax.tree.map(lambda v: v.mean(0), c))[0]
        assert float(jnp.abs(mean_c).max()) < 1e-4


# ---------------------------------------------------------------------------
# sparse neighbor-gather gossip at scale (n = 1024)
# ---------------------------------------------------------------------------
# The scaling claim of the sparse tentpole: the SAME invariants the dense
# suite pins above must hold on the sparse_packed path at a client count
# where the dense path would refuse to materialize W.  One shared compiled
# step (lru_cache) keeps the n=1024 cost to a single trace per topology
# shape; example counts stay small because each example runs real rounds
# over 1024 clients.

N_SCALE = 1024


@functools.lru_cache(maxsize=1)
def _sparse_scale_setup():
    from repro.core import sparse_topology as sparse

    n, k = N_SCALE, 2
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, n, dx=4, dy=2, heterogeneity=1.5)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(num_clients=n, local_steps=k, eta_cx=0.01,
                          eta_cy=0.05, eta_sx=0.4, eta_sy=0.4,
                          topology="exp", mixing_impl="sparse_packed",
                          gossip_backend="xla")
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    stt = init_state(prob, cfg, key, init_batch=cb,
                     init_keys=jax.random.split(key, n))
    step = jax.jit(make_round_step(prob, cfg, traced_w=True,
                                   participation=True))
    return n, k, stt, step, kb, sparse.sparse_exp(n)


def _sum_c_small(stt, tol=1e-3):
    # n=1024 f32 client means accumulate more rounding than the n≤8 suite;
    # Σc stays orders of magnitude under the tracking signal either way
    for c in (stt.cx, stt.cy):
        mean_c = jax.tree.leaves(jax.tree.map(lambda v: v.mean(0), c))[0]
        assert float(jnp.abs(mean_c).max()) < tol


@given(family=st.sampled_from(["erdos_renyi", "dropout", "pairwise"]),
       edge_prob=st.floats(0.2, 0.9), drop=st.floats(0.0, 0.5),
       seed=st.integers(0, 50))
@settings(max_examples=4, deadline=None)
def test_sparse_scale_sum_c_and_freeze_n1024(family, edge_prob, drop, seed):
    """Σ_i c_i = 0 and bit-exact inactive-client freeze on the sparse path
    at n=1024, under per-round sampled sparse Ws (every family, so the
    realized degree distribution varies per example) and Bernoulli
    participation masks."""
    from repro.core import sparse_topology as sparse

    n, k, stt, step, kb, support = _sparse_scale_setup()
    w_fn = sparse.make_sparse_w_sampler(
        family, support, jax.random.PRNGKey(seed), edge_prob=edge_prob,
        client_drop_prob=drop)
    mask_fn = stoch.make_participation_sampler(n, jax.random.PRNGKey(seed),
                                               1.0 - drop)
    for t in range(2):
        keys = jax.random.split(jax.random.PRNGKey(seed + t),
                                k * n).reshape(k, n, 2)
        mask = mask_fn(jnp.int32(t))
        prev = stt
        stt = step(stt, kb, keys, w_fn(jnp.int32(t)), mask)
        inactive = ~np.asarray(mask)
        for name in ("x", "y", "cx", "cy"):
            np.testing.assert_array_equal(
                np.asarray(getattr(stt, name))[inactive],
                np.asarray(getattr(prev, name))[inactive], err_msg=name)
        _sum_c_small(stt)


@given(seed=st.integers(0, 100), r_a=st.integers(0, 500),
       r_b=st.integers(501, 1000))
@settings(max_examples=3, deadline=None)
def test_sparse_scale_mean_dynamics_w_independent_n1024(seed, r_a, r_b):
    """From a common state, one round under two DIFFERENT sparse W draws
    moves the client mean identically — the W-independence of the mean
    dynamics, at a scale where W is never materialized."""
    from repro.core import sparse_topology as sparse

    n, k, stt, step, kb, support = _sparse_scale_setup()
    w_fn = sparse.make_sparse_w_sampler(
        "erdos_renyi", support, jax.random.PRNGKey(seed), edge_prob=0.6)
    ones = jnp.ones((n,), bool)
    keys = jax.random.split(jax.random.PRNGKey(seed), k * n).reshape(k, n, 2)
    out_a = step(stt, kb, keys, w_fn(jnp.int32(r_a)), ones)
    out_b = step(stt, kb, keys, w_fn(jnp.int32(r_b)), ones)
    np.testing.assert_allclose(mean_over_clients(out_a.x),
                               mean_over_clients(out_b.x),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(mean_over_clients(out_a.y),
                               mean_over_clients(out_b.y),
                               rtol=1e-4, atol=1e-4)
    _sum_c_small(out_a)


@given(topo=st.sampled_from(["ring", "torus", "exp", "hierarchical"]),
       seed=st.integers(0, 50))
@settings(max_examples=4, deadline=None)
def test_sparse_scale_static_topologies_n1024(topo, seed):
    """Structured degree distributions at n=1024 (constant-degree ring and
    torus, log-degree exp graph, two-tier hierarchical): one sparse round
    holds Σc = 0.  1024 = 32², so every family exists at this n."""
    from repro.core import sparse_topology as sparse

    n, k, stt, step, kb, _ = _sparse_scale_setup()
    sp = (sparse.sparse_hierarchical(n, cluster_size=32)
          if topo == "hierarchical" else sparse.sparse_mixing_matrix(topo, n))
    ones = jnp.ones((n,), bool)
    keys = jax.random.split(jax.random.PRNGKey(seed), k * n).reshape(k, n, 2)
    _sum_c_small(step(stt, kb, keys, sp, ones))


@given(n=st.sampled_from([2, 4, 8]), mask_bits=st.integers(0, 2**8 - 1),
       seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_participation_invariants_sparse_engine(n, mask_bits, seed):
    """The small-n cousin: the full check_participation_invariants battery
    (mean dynamics vs W=J, Σc, bit-exact freeze) through sparse_packed."""
    from test_kgt import check_participation_invariants

    check_participation_invariants("kgt_minimax", n=n, k=2, seed=seed,
                                   mask_bits=mask_bits,
                                   mixing_impl="sparse_packed")


@given(n=st.integers(2, 20))
@settings(max_examples=20, deadline=None)
def test_spectral_gap_in_unit_interval(n):
    for topo in ("ring", "full", "exp"):
        p = spectral_gap(mixing_matrix(topo, n))
        assert 0.0 < p <= 1.0 + 1e-9


@given(b=st.integers(1, 3), s=st.integers(2, 40), w=st.integers(1, 16),
       seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_rglru_kernel_property(b, s, w, seed):
    """Kernel == oracle for arbitrary small shapes (incl. ragged padding)."""
    key = jax.random.PRNGKey(seed)
    a = jax.nn.sigmoid(jax.random.normal(key, (b, s, w)))
    u = jax.random.normal(jax.random.fold_in(key, 1), (b, s, w)) * 0.3
    out = rglru_scan(a, u, chunk=16, backend="interpret")
    ref = rglru_scan(a, u, chunk=16, backend="xla")
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@given(algo=st.sampled_from(["kgt_minimax", "dsgda", "local_sgda", "gt_gda"]),
       n=st.integers(2, 8), k=st.integers(1, 4), seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_round_step_invariants_any_doubly_stochastic_w(algo, n, k, seed):
    """One full round_step under an arbitrary (random, symmetric) doubly-
    stochastic W — not just the named topologies — preserves the client-mean
    dynamics of x and y (x̄ evolves exactly as under W = J) and keeps
    Σ_i c_i ≈ 0 (Lemma 8), for all four algorithm variants.

    ``doubly_stochastic_w`` / ``check_round_mean_dynamics`` live in
    test_kgt.py, where a deterministic cousin of this test runs even where
    hypothesis is unavailable.
    """
    from test_kgt import check_round_mean_dynamics

    check_round_mean_dynamics(algo, n=n, k=k, seed=seed)


@given(n=st.sampled_from([2, 4, 8]), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_packed_round_invariants_any_doubly_stochastic_w(n, seed):
    """Same invariants through the pallas_packed fused round engine."""
    from test_kgt import check_round_mean_dynamics

    check_round_mean_dynamics("kgt_minimax", n=n, k=2, seed=seed,
                              mixing_impl="pallas_packed")


@given(seed=st.integers(0, 30))
@settings(max_examples=10, deadline=None)
def test_round_step_average_dynamics_fullmesh(seed):
    """With W=J the averaged iterate is invariant to which client held what:
    permuting client identities leaves x̄ unchanged."""
    n, k = 4, 2
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=4, dy=2)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(num_clients=n, local_steps=k, eta_cx=0.01,
                          eta_cy=0.05, topology="full")
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    stt = init_state(prob, cfg, key, init_batch=cb,
                     init_keys=jax.random.split(key, n))
    step = make_round_step(prob, cfg)
    keys = jax.random.split(jax.random.PRNGKey(1), k * n).reshape(k, n, 2)
    out1 = mean_over_clients(step(stt, kb, keys).x)

    perm = np.array([2, 3, 0, 1])
    stt_p = jax.tree.map(lambda v: v[perm] if v.ndim > 0 else v, stt)
    kb_p = jax.tree.map(lambda v: v[:, perm], kb)
    keys_p = keys[:, perm]
    out2 = mean_over_clients(step(stt_p, kb_p, keys_p).x)
    np.testing.assert_allclose(out1, out2, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# stochastic topologies + partial participation (the churn tentpole)
# ---------------------------------------------------------------------------

def _assert_doubly_stochastic(w, n):
    w = np.asarray(w)
    assert w.shape == (n, n)
    np.testing.assert_allclose(w, w.T, atol=1e-6)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-5)
    assert (w >= -1e-6).all()


@given(family=st.sampled_from(["erdos_renyi", "pairwise", "dropout"]),
       n=st.integers(2, 12), round_idx=st.integers(0, 1000),
       edge_prob=st.floats(0.0, 1.0), drop=st.floats(0.0, 1.0),
       seed=st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_sampled_family_w_is_doubly_stochastic(family, n, round_idx,
                                               edge_prob, drop, seed):
    """Every topology family draws a symmetric doubly-stochastic W for any
    round index, edge probability, and drop probability — Assumption 4
    minus the fixed spectral gap, which is exactly what the mean-dynamics
    and Σc = 0 invariants need."""
    w_fn = stoch.make_w_sampler(
        family, n, jax.random.PRNGKey(seed),
        base_w=mixing_matrix("full", n), edge_prob=edge_prob,
        client_drop_prob=drop)
    _assert_doubly_stochastic(w_fn(jnp.int32(round_idx)), n)


@given(n=st.integers(1, 12), mask_bits=st.integers(0, 2**12 - 1),
       seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_masked_w_self_loop_fallback(n, mask_bits, seed):
    """masked_w keeps ANY doubly-stochastic W doubly stochastic under ANY
    mask (all-zero and all-one included), and collapses masked-out clients'
    rows/columns to e_i exactly."""
    from test_kgt import doubly_stochastic_w

    mask = np.array([(mask_bits >> i) & 1 == 1 for i in range(n)])
    w = stoch.masked_w(doubly_stochastic_w(n, seed), jnp.asarray(mask))
    _assert_doubly_stochastic(w, n)
    w = np.asarray(w)
    for i in np.flatnonzero(~mask):
        np.testing.assert_array_equal(w[i], np.eye(n)[i])
        np.testing.assert_array_equal(w[:, i], np.eye(n)[i])


@given(algo=st.sampled_from(["kgt_minimax", "dsgda", "local_sgda", "gt_gda"]),
       n=st.integers(2, 8), k=st.integers(1, 4),
       mask_bits=st.integers(0, 2**8 - 1), seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_participation_mean_dynamics_and_sum_c(algo, n, k, mask_bits, seed):
    """Under an arbitrary participation mask and an arbitrary random
    doubly-stochastic W: the client-mean dynamics are W-independent, Σ_i
    c_i = 0 (Lemma 8 survives churn because the masked W stays doubly
    stochastic), and inactive clients freeze bit-exactly.  Helper shared
    with the deterministic cousins in test_kgt.py."""
    from test_kgt import check_participation_invariants

    check_participation_invariants(algo, n=n, k=k, seed=seed,
                                   mask_bits=mask_bits)


@given(n=st.sampled_from([2, 4, 8]), mask_bits=st.integers(0, 2**8 - 1),
       seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_participation_invariants_packed_engine(n, mask_bits, seed):
    """Same churn invariants through the pallas_packed fused round engine
    (traced W + mask as kernel-feeding operands)."""
    from test_kgt import check_participation_invariants

    check_participation_invariants("kgt_minimax", n=n, k=2, seed=seed,
                                   mask_bits=mask_bits,
                                   mixing_impl="pallas_packed")


# ---------------------------------------------------------------------------
# error-feedback compressed gossip (the fused-round tentpole)
# ---------------------------------------------------------------------------

@given(impl=st.sampled_from(["pallas_packed", "fused_round"]),
       method=st.sampled_from(["bf16", "int8"]),
       n=st.sampled_from([2, 4, 8]), het=st.floats(0.0, 3.0),
       seed=st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_sum_c_zero_under_compressed_gossip(impl, method, n, het, seed):
    """Lossy quantization must not break Lemma 8: the transmitted q rides
    both the correction and the mixing, so Σ_i c_i = 0 telescopes exactly
    through bf16/int8 error-feedback gossip on either packed lowering."""
    k = 2
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=5, dy=3, heterogeneity=het)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(num_clients=n, local_steps=k, eta_cx=0.01,
                          eta_cy=0.05, eta_sx=0.4, eta_sy=0.4,
                          topology="ring", mixing_impl=impl,
                          gossip_backend="xla", gossip_compress=method)
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    stt = init_state(prob, cfg, key, init_batch=cb,
                     init_keys=jax.random.split(key, n))
    step = jax.jit(make_round_step(prob, cfg))
    for t in range(3):
        keys = jax.random.split(jax.random.PRNGKey(t), k * n).reshape(k, n, 2)
        stt = step(stt, kb, keys)
    for c in (stt.cx, stt.cy):
        mean_c = jax.tree.leaves(jax.tree.map(lambda v: v.mean(0), c))[0]
        assert float(jnp.abs(mean_c).max()) < 1e-4


@given(impl=st.sampled_from(["pallas_packed", "fused_round"]),
       method=st.sampled_from(["bf16", "int8"]),
       mask_bits=st.integers(0, 2**6 - 1), seed=st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_inactive_freeze_bitexact_under_compression(impl, method, mask_bits,
                                                    seed):
    """Churn × compression: an inactive client's (θ, c) AND its banked EF
    residual freeze bit-exactly for any participation mask — a frozen
    client must neither transmit nor lose carried quantization error."""
    n, k = 6, 2
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=5, dy=3, heterogeneity=1.0)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(num_clients=n, local_steps=k, eta_cx=0.01,
                          eta_cy=0.05, eta_sx=0.4, eta_sy=0.4,
                          topology="full", mixing_impl=impl,
                          gossip_backend="xla", gossip_compress=method)
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    stt = init_state(prob, cfg, key, init_batch=cb,
                     init_keys=jax.random.split(key, n))
    step = jax.jit(make_round_step(prob, cfg, participation=True))
    mask = jnp.asarray([(mask_bits >> i) & 1 == 1 for i in range(n)])
    # one all-active round first so the EF residual is nonzero when frozen
    keys = jax.random.split(jax.random.PRNGKey(seed), k * n).reshape(k, n, 2)
    stt = step(stt, kb, keys, jnp.ones((n,), bool))
    out = step(stt, kb, keys, mask)
    inactive = ~np.asarray(mask)
    for name in ("x", "y", "cx", "cy", "ef_x", "ef_y"):
        np.testing.assert_array_equal(
            np.asarray(getattr(out, name))[inactive],
            np.asarray(getattr(stt, name))[inactive],
            err_msg=f"{impl}/{method}:{name}")
    for c in (out.cx, out.cy):
        mean_c = jax.tree.leaves(jax.tree.map(lambda v: v.mean(0), c))[0]
        assert float(jnp.abs(mean_c).max()) < 1e-4


# ---------------------------------------------------------------------------
# Byzantine adversary axis (the robust-aggregation tentpole)
# ---------------------------------------------------------------------------

@given(attack=st.sampled_from(["sign_flip", "large_norm", "random_noise"]),
       n=st.integers(3, 8), f=st.integers(1, 2), scale=st.floats(0.5, 4.0),
       seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_sum_c_zero_under_any_attack_linear_gossip(attack, n, f, scale,
                                                   seed):
    """The attacker follows the protocol with its corrupted Δ, so Σ_i c_i =
    0 survives every attack under linear doubly stochastic gossip — an
    attacked Δ is still just a Δ.  (The robust aggregations deliberately
    give this identity up; see the freeze property below for their check.)"""
    from repro.core import adversary as adversary_lib

    k = 2
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=5, dy=3, heterogeneity=1.0)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(num_clients=n, local_steps=k, eta_cx=0.01,
                          eta_cy=0.05, eta_sx=0.4, eta_sy=0.4,
                          topology="ring")
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    stt = init_state(prob, cfg, key, init_batch=cb,
                     init_keys=jax.random.split(key, n))
    step = make_round_step(prob, cfg, byzantine=True)
    fn = adversary_lib.make_attack_sampler(
        n, key, num_byzantine=min(f, n - 1), attack=attack, scale=scale)
    for t in range(2):
        keys = jax.random.split(jax.random.PRNGKey(t), k * n).reshape(k, n, 2)
        stt = step(stt, kb, keys, fn(jnp.int32(t)))
    for c in (stt.cx, stt.cy):
        cl = jax.tree.leaves(c)[0]
        # large_norm at scale 4 drives |c| to ~1e4 — the f32 mean's rounding
        # floor scales with the correction magnitude, so the tolerance does
        mean_c = float(jnp.abs(cl.mean(0)).max())
        assert mean_c < 1e-5 * (1.0 + float(jnp.abs(cl).max()))


@given(impl=st.sampled_from(["dense", "coord_median", "trimmed_mean",
                             "sparse_trimmed_mean"]),
       attack=st.sampled_from(["sign_flip", "large_norm", "random_noise"]),
       mask_bits=st.integers(0, 2**6 - 1), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_inactive_freeze_under_attack_any_aggregation(impl, attack,
                                                      mask_bits, seed):
    """Participation composes with the adversary slot on every epilogue —
    linear, dense-robust, and sparse-robust alike: an inactive client
    (attacker or honest) freezes (θ, c) bit-exactly for ANY mask, attack,
    and aggregation rule."""
    from repro.core import adversary as adversary_lib

    n, k = 6, 2
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=4, dy=2, heterogeneity=1.0)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(num_clients=n, local_steps=k, eta_cx=0.01,
                          eta_cy=0.05, eta_sx=0.4, eta_sy=0.4,
                          topology="full", mixing_impl=impl)
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    stt = init_state(prob, cfg, key, init_batch=cb,
                     init_keys=jax.random.split(key, n))
    step = make_round_step(prob, cfg, participation=True, byzantine=True)
    fn = adversary_lib.make_attack_sampler(n, key, num_byzantine=2,
                                           attack=attack, scale=3.0)
    mask = jnp.asarray([(mask_bits >> i) & 1 == 1 for i in range(n)])
    keys = jax.random.split(jax.random.PRNGKey(seed), k * n).reshape(k, n, 2)
    out = step(stt, kb, keys, mask, fn(jnp.int32(0)))
    inactive = ~np.asarray(mask)
    for name in ("x", "y", "cx", "cy"):
        np.testing.assert_array_equal(
            np.asarray(getattr(out, name))[inactive],
            np.asarray(getattr(stt, name))[inactive],
            err_msg=f"{impl}/{attack}:{name}")


@given(rule=st.sampled_from(["coord_median", "trimmed_mean"]),
       trim=st.integers(1, 3), n=st.integers(2, 8), d=st.integers(1, 9),
       seed=st.integers(0, 200))
@settings(max_examples=15, deadline=None)
def test_robust_reduce_oracle_parity_property(rule, trim, n, d, seed):
    """mixing._robust_reduce == kernels.ref.robust_agg_ref for arbitrary
    shapes, valid masks, and injected non-finite values (the oracle takes a
    different float path — nanmedian / descending sort)."""
    from repro.core.mixing import _robust_reduce
    from repro.kernels.ref import robust_agg_ref

    m = n + 1
    key = jax.random.PRNGKey(seed)
    vals = jax.random.normal(key, (n, m, d)) * 2.0
    vals = jnp.where(
        jax.random.uniform(jax.random.fold_in(key, 1), (n, m, d)) < 0.15,
        jnp.inf, vals)
    valid = jax.random.uniform(jax.random.fold_in(key, 2), (n, m)) < 0.6
    valid = valid.at[:, 0].set(True)
    vals = vals.at[:, 0, :].set(
        jax.random.normal(jax.random.fold_in(key, 3), (n, d)))
    np.testing.assert_allclose(
        _robust_reduce(vals, valid, rule, trim),
        robust_agg_ref(vals, valid, rule=rule, trim=trim),
        rtol=1e-5, atol=1e-6)


@given(family=st.sampled_from(["erdos_renyi", "pairwise", "dropout"]),
       n=st.integers(2, 6), edge_prob=st.floats(0.1, 0.9),
       rate=st.floats(0.0, 1.0), seed=st.integers(0, 200))
@settings(max_examples=12, deadline=None)
def test_sum_c_zero_under_sampled_w_sequences(family, n, edge_prob, rate,
                                              seed):
    """Σ_i c_i stays 0 across rounds of a *sequence* of per-round sampled
    Ws and Bernoulli participation masks — the setting the engine actually
    runs under churn."""
    k = 2
    key = jax.random.PRNGKey(seed)
    data = make_quadratic_data(key, n, dx=5, dy=3, heterogeneity=1.5)
    prob = quadratic_problem(data, sigma=0.0)
    cfg = AlgorithmConfig(num_clients=n, local_steps=k, eta_cx=0.01,
                          eta_cy=0.05, eta_sx=0.4, eta_sy=0.4)
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    stt = init_state(prob, cfg, key, init_batch=cb,
                     init_keys=jax.random.split(key, n))
    step = jax.jit(make_round_step(prob, cfg, traced_w=True,
                                   participation=True))
    w_fn = stoch.make_w_sampler(family, n, key,
                                base_w=mixing_matrix("full", n),
                                edge_prob=edge_prob, client_drop_prob=0.4)
    mask_fn = stoch.make_participation_sampler(n, key, rate)
    for t in range(3):
        keys = jax.random.split(jax.random.PRNGKey(t), k * n).reshape(k, n, 2)
        stt = step(stt, kb, keys, w_fn(jnp.int32(t)), mask_fn(jnp.int32(t)))
    for c in (stt.cx, stt.cy):
        mean_c = jax.tree.leaves(jax.tree.map(lambda v: v.mean(0), c))[0]
        assert float(jnp.abs(mean_c).max()) < 1e-4
