"""Parity + invariant suite for the sparse neighbor-gather gossip path.

The padded-CSR topology (``core.sparse_topology``) and the neighbor-gather
round epilogue (``kernels.neighbor_gossip`` via ``ops.sparse_gossip_round``)
must reproduce the dense path bit-for-bit where exactness is claimed
(densify/from_dense round trips) and to ≤1e-6 elsewhere (kernel vs dense
oracle, masked mixing, full-round trajectories) — across topology families,
participation masks, and gossip dtypes.  The dense-materialization guard in
``stochastic_topology`` is pinned here too: past n=512 the dense samplers
must refuse loudly instead of silently allocating (n, n).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AlgorithmConfig
from repro.core import (
    init_state,
    make_quadratic_data,
    make_round_step,
    mean_over_clients,
    quadratic_problem,
)
from repro.core import sparse_topology as sparse
from repro.core import stochastic_topology as stoch
from repro.core import topology

# every named topology has a sparse twin; torus needs square n
TOPO_CLIENTS = (("ring", 2), ("ring", 5), ("ring", 8), ("torus", 9),
                ("torus", 16), ("exp", 8), ("exp", 12), ("full", 8),
                ("star", 8))


def _operands(n, d, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    delta = jax.random.normal(ks[0], (n, d), jnp.float32)
    theta = jax.random.normal(ks[1], (n, d), jnp.float32) * 3.0
    c = jax.random.normal(ks[2], (n, d), jnp.float32) * 0.5
    return delta, theta, c


# ---------------------------------------------------------------------------
# constructors: sparse twins of the dense topologies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo,n", TOPO_CLIENTS)
def test_sparse_constructors_match_dense(topo, n):
    """densify(sparse_<topo>(n)) reproduces topology.mixing_matrix — the
    Metropolis–Hastings weights coincide with the dense constructors on
    every named family."""
    w_sparse = sparse.densify(sparse.sparse_mixing_matrix(topo, n))
    w_dense = np.asarray(topology.mixing_matrix(topo, n), np.float32)
    np.testing.assert_allclose(np.asarray(w_sparse), w_dense,
                               rtol=0, atol=1e-7)


@pytest.mark.parametrize("topo,n", TOPO_CLIENTS)
def test_densify_from_dense_bit_roundtrip(topo, n):
    """from_dense → densify is bit-exact: padding slots carry weight 0.0
    and scatter-add of exact zeros changes nothing."""
    w = jnp.asarray(topology.mixing_matrix(topo, n), jnp.float32)
    sp = sparse.from_dense(np.asarray(w))
    np.testing.assert_array_equal(np.asarray(sparse.densify(sp)),
                                  np.asarray(w))


def test_from_dense_roundtrip_random_doubly_stochastic():
    from test_kgt import doubly_stochastic_w

    w = np.asarray(doubly_stochastic_w(10, seed=3), np.float32)
    sp = sparse.from_dense(w)
    np.testing.assert_array_equal(np.asarray(sparse.densify(sp)), w)
    assert sp.max_degree == 9 and sp.num_edges == 10 * 9


def test_sparse_torus_rejects_nonsquare():
    with pytest.raises(ValueError, match="square"):
        sparse.sparse_torus(8)


def test_sparse_mixing_matrix_rejects_unknown():
    with pytest.raises(KeyError, match="unknown topology"):
        sparse.sparse_mixing_matrix("petersen", 10)


def test_sparse_topology_shapes_and_edges():
    sp = sparse.sparse_ring(8)
    assert sp.n == 8 and sp.max_degree == 2 and sp.num_edges == 16
    assert sp.neighbor_idx.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(sp.degree), np.full(8, 2))
    np.testing.assert_array_equal(np.asarray(sp.offsets),
                                  np.arange(0, 18, 2))


def test_hierarchical_cluster_of_clusters():
    """n=24 in 6 clusters of 4: intra-cluster full mesh + a leader ring;
    symmetric, doubly stochastic, and much sparser than full."""
    sp = sparse.sparse_hierarchical(24, cluster_size=4)
    w = np.asarray(sparse.densify(sp))
    np.testing.assert_allclose(w, w.T, atol=1e-7)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-6)
    assert (w >= 0).all()
    # non-leader clients only see their own cluster (3 peers); leaders see
    # the cluster plus two ring neighbors
    deg = np.asarray(sp.degree)
    assert deg.max() == 5 and np.sum(deg == 5) == 6 and np.sum(deg == 3) == 18
    with pytest.raises(ValueError, match="cluster_size must divide n"):
        sparse.sparse_hierarchical(10, cluster_size=4)


# ---------------------------------------------------------------------------
# per-round samplers on a sparse support
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family",
                         ["static", "erdos_renyi", "pairwise", "dropout"])
def test_sparse_sampler_draws_doubly_stochastic_on_support(family):
    n = 12
    support = sparse.sparse_exp(n)
    sup_mask = np.asarray(sparse.densify(support)) > 0
    w_fn = sparse.make_sparse_w_sampler(
        family, support, jax.random.PRNGKey(7), edge_prob=0.4,
        client_drop_prob=0.3)
    draw = jax.jit(lambda r: sparse.densify(w_fn(r)))
    for r in (0, 3, 17):
        w = np.asarray(draw(jnp.int32(r)))
        np.testing.assert_allclose(w, w.T, atol=1e-6)
        np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-5)
        assert (w >= -1e-6).all()
        # never an edge outside the support graph (+ diagonal)
        assert (w[~(sup_mask | np.eye(n, dtype=bool))] == 0).all()


def test_sparse_sampler_deterministic_per_round():
    support = sparse.sparse_ring(8)
    w_fn = sparse.make_sparse_w_sampler("erdos_renyi", support,
                                        jax.random.PRNGKey(0), edge_prob=0.6)
    a = w_fn(jnp.int32(5))
    b = w_fn(jnp.int32(5))
    c = w_fn(jnp.int32(6))
    np.testing.assert_array_equal(np.asarray(a.neighbor_w),
                                  np.asarray(b.neighbor_w))
    assert not np.array_equal(np.asarray(a.neighbor_w),
                              np.asarray(c.neighbor_w))


def test_sparse_sampler_matches_dense_dropout_family():
    """The dropout family reuses the dense family's Bernoulli draws, so the
    sparse draw must densify to exactly masked_w(base, keep)."""
    n = 8
    base = topology.mixing_matrix("exp", n)
    support = sparse.sparse_exp(n)
    key = jax.random.PRNGKey(3)
    w_dense_fn = stoch.make_w_sampler("dropout", n, key, base_w=base,
                                      client_drop_prob=0.4)
    w_sparse_fn = sparse.make_sparse_w_sampler("dropout", support, key,
                                               client_drop_prob=0.4)
    for r in (0, 2, 9):
        np.testing.assert_allclose(
            np.asarray(sparse.densify(w_sparse_fn(jnp.int32(r)))),
            np.asarray(w_dense_fn(jnp.int32(r))), rtol=0, atol=1e-6)


def test_sparse_sampler_rejects_unknown_family():
    with pytest.raises(ValueError, match="unknown topology family"):
        sparse.make_sparse_w_sampler("smallworld", sparse.sparse_ring(4),
                                     jax.random.PRNGKey(0))


def test_pair_slots_rejects_asymmetric_support():
    sp = sparse.sparse_ring(6)
    # break symmetry: client 0 lists 3 as a neighbor, 3 doesn't list 0
    nidx = np.asarray(sp.neighbor_idx).copy()
    nidx[0, 0] = 3
    with pytest.raises(ValueError, match="not symmetric"):
        sparse._pair_slots(nidx, np.asarray(sp.degree))


@pytest.mark.parametrize("all_active", [False, True])
def test_sparse_masked_w_matches_dense(all_active):
    n = 9
    sp = sparse.sparse_torus(n)
    mask = (jnp.ones(n, bool) if all_active
            else jnp.asarray([1, 0, 1, 1, 0, 1, 0, 1, 1], bool))
    got = sparse.densify(sparse.sparse_masked_w(sp, mask))
    want = stoch.masked_w(sparse.densify(sp), mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-6)
    if not all_active:
        w = np.asarray(got)
        for i in np.flatnonzero(~np.asarray(mask)):
            np.testing.assert_array_equal(w[i], np.eye(n)[i])


# ---------------------------------------------------------------------------
# neighbor-gather epilogue vs dense oracle
# ---------------------------------------------------------------------------

from repro.kernels import ops  # noqa: E402


@pytest.mark.parametrize("gossip_dtype", [None, "bfloat16"])
@pytest.mark.parametrize("topo,n", TOPO_CLIENTS)
def test_sparse_gossip_matches_dense_oracle(topo, n, gossip_dtype):
    """sparse_gossip_round (xla) vs fused_gossip_round (xla) on the
    densified W — identical operand-narrowing rules, so bf16 agrees to the
    same ≤1e-6 as f32."""
    sp = sparse.sparse_mixing_matrix(topo, n)
    d = 96 + n  # not a lane multiple
    delta, theta, c = _operands(n, d, seed=n)
    t_s, c_s = ops.sparse_gossip_round(
        sp.neighbor_idx, sp.neighbor_w, sp.self_w, delta, theta, c, 0.7, 4.2,
        backend="xla", gossip_dtype=gossip_dtype)
    t_d, c_d = ops.fused_gossip_round(
        sparse.densify(sp), delta, theta, c, 0.7, 4.2, backend="xla",
        gossip_dtype=gossip_dtype)
    # gather-sum vs dense matmul accumulate in different orders — one ulp
    # past 1e-6 on f32 operands of magnitude ~5
    np.testing.assert_allclose(t_s, t_d, rtol=0, atol=2e-6)
    np.testing.assert_allclose(c_s, c_d, rtol=0, atol=2e-6)


@pytest.mark.parametrize("topo,n", (("ring", 8), ("torus", 9), ("exp", 8)))
def test_sparse_kernel_matches_xla(topo, n):
    """The Pallas neighbor-gather kernel (interpret mode) vs the pure-jnp
    sparse oracle."""
    sp = sparse.sparse_mixing_matrix(topo, n)
    d = 384 + n
    delta, theta, c = _operands(n, d, seed=n)
    args = (sp.neighbor_idx, sp.neighbor_w, sp.self_w, delta, theta, c,
            0.7, 4.2)
    t_k, c_k = ops.sparse_gossip_round(*args, backend="interpret")
    t_r, c_r = ops.sparse_gossip_round(*args, backend="xla")
    np.testing.assert_allclose(t_k, t_r, rtol=0, atol=1e-6)
    np.testing.assert_allclose(c_k, c_r, rtol=0, atol=1e-6)


@pytest.mark.parametrize("d", [1, 127, 128, 513, 640])
def test_sparse_kernel_ragged_d_tile_padding(d):
    n = 4
    sp = sparse.sparse_exp(n)
    delta, theta, c = _operands(n, d, seed=d)
    args = (sp.neighbor_idx, sp.neighbor_w, sp.self_w, delta, theta, c,
            1.3, -2.0)
    t_k, c_k = ops.sparse_gossip_round(*args, backend="interpret")
    t_r, c_r = ops.sparse_gossip_round(*args, backend="xla")
    assert t_k.shape == c_k.shape == (n, d)
    np.testing.assert_allclose(t_k, t_r, rtol=0, atol=1e-6)
    np.testing.assert_allclose(c_k, c_r, rtol=0, atol=1e-6)


@pytest.mark.parametrize("backend", ["xla", "interpret"])
@pytest.mark.parametrize("family", ["erdos_renyi", "pairwise", "dropout"])
def test_sparse_gossip_sampled_w_masked_matches_dense(family, backend):
    """Per-round sampled sparse W + participation mask through the sparse
    epilogue vs the dense fused epilogue on the densified draw."""
    n, d = 12, 200
    support = sparse.sparse_exp(n)
    w_fn = sparse.make_sparse_w_sampler(family, support, jax.random.PRNGKey(7),
                                        edge_prob=0.5, client_drop_prob=0.3)
    mask_fn = stoch.make_participation_sampler(n, jax.random.PRNGKey(9), 0.6)
    for r in (0, 3):
        sp = sparse.sparse_masked_w(w_fn(jnp.int32(r)), mask_fn(jnp.int32(r)))
        delta, theta, c = _operands(n, d, seed=r)
        t_s, c_s = ops.sparse_gossip_round(
            sp.neighbor_idx, sp.neighbor_w, sp.self_w, delta, theta, c,
            0.7, 4.2, backend=backend)
        t_d, c_d = ops.fused_gossip_round(
            sparse.densify(sp), delta, theta, c, 0.7, 4.2, backend="xla")
        np.testing.assert_allclose(t_s, t_d, rtol=0, atol=2e-6)
        np.testing.assert_allclose(c_s, c_d, rtol=0, atol=2e-6)


def test_sparse_mix_matches_dense_matmul():
    n, d = 9, 33
    sp = sparse.sparse_torus(n)
    buf = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    got = sparse.sparse_mix(sp, buf)
    want = np.asarray(sparse.densify(sp)) @ np.asarray(buf)
    np.testing.assert_allclose(np.asarray(got), want, rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# full-round engine: sparse_packed vs dense trajectories
# ---------------------------------------------------------------------------

def _traj(impl, backend, topo="ring", n=8, rounds=4, algo="kgt_minimax"):
    key = jax.random.PRNGKey(0)
    data = make_quadratic_data(key, n, dx=6, dy=3, heterogeneity=2.0)
    prob = quadratic_problem(data, sigma=0.0)
    k = 2
    cfg = AlgorithmConfig(algorithm=algo, num_clients=n, local_steps=k,
                          eta_cx=0.01, eta_cy=0.1, eta_sx=0.5, eta_sy=0.5,
                          topology=topo, mixing_impl=impl,
                          gossip_backend=backend)
    cb = {kk: v for kk, v in data.items() if kk != "mu"}
    kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)), cb)
    stt = init_state(prob, cfg, key, init_batch=cb,
                     init_keys=jax.random.split(key, n))
    step = jax.jit(make_round_step(prob, cfg))
    for t in range(rounds):
        keys = jax.random.split(jax.random.PRNGKey(t), k * n).reshape(k, n, 2)
        stt = step(stt, kb, keys)
    return stt


@pytest.mark.parametrize("topo", ["ring", "exp", "full"])
def test_sparse_round_matches_dense_trajectory(topo):
    dense = _traj("dense", "auto", topo=topo)
    sp = _traj("sparse_packed", "xla", topo=topo)
    for name in ("x", "y", "cx", "cy"):
        for a, b in zip(jax.tree.leaves(getattr(dense, name)),
                        jax.tree.leaves(getattr(sp, name))):
            np.testing.assert_allclose(a, b, rtol=0, atol=2e-5,
                                       err_msg=f"{topo}/{name}")


@pytest.mark.parametrize("algo", ["kgt_minimax", "dsgda", "local_sgda",
                                  "gt_gda"])
def test_sparse_round_matches_dense_all_variants(algo):
    dense = _traj("dense", "auto", algo=algo)
    sp = _traj("sparse_packed", "xla", algo=algo)
    for name in ("x", "y", "cx", "cy"):
        for a, b in zip(jax.tree.leaves(getattr(dense, name)),
                        jax.tree.leaves(getattr(sp, name))):
            np.testing.assert_allclose(a, b, rtol=0, atol=2e-5,
                                       err_msg=f"{algo}/{name}")


def test_sparse_round_interpret_kernel_backend():
    """The Pallas neighbor-gather kernel drives the full round too."""
    xla = _traj("sparse_packed", "xla", rounds=2)
    interp = _traj("sparse_packed", "interpret", rounds=2)
    for a, b in zip(jax.tree.leaves(xla.x), jax.tree.leaves(interp.x)):
        np.testing.assert_allclose(a, b, rtol=0, atol=1e-6)


@pytest.mark.parametrize("family", ["erdos_renyi", "dropout"])
def test_sparse_round_under_churn_matches_dense(family):
    """Traced sparse W + participation mask through make_round_step: the
    dense arm consumes densify() of the same draw, so the trajectories must
    agree — and inactive clients freeze bit-exactly on the sparse path."""
    n, k = 8, 2
    key = jax.random.PRNGKey(5)
    data = make_quadratic_data(key, n, dx=6, dy=3, heterogeneity=1.5)
    prob = quadratic_problem(data, sigma=0.0)
    support = sparse.sparse_exp(n)
    w_fn = sparse.make_sparse_w_sampler(family, support,
                                        jax.random.PRNGKey(11),
                                        edge_prob=0.5, client_drop_prob=0.3)
    mask_fn = stoch.make_participation_sampler(n, jax.random.PRNGKey(9), 0.7)
    outs = {}
    for impl in ("dense", "sparse_packed"):
        cfg = AlgorithmConfig(num_clients=n, local_steps=k, eta_cx=0.01,
                              eta_cy=0.1, eta_sx=0.5, eta_sy=0.5,
                              topology="exp", mixing_impl=impl,
                              gossip_backend="xla")
        cb = {kk: v for kk, v in data.items() if kk != "mu"}
        kb = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (k, *v.shape)),
                          cb)
        stt = init_state(prob, cfg, key, init_batch=cb,
                         init_keys=jax.random.split(key, n))
        step = jax.jit(make_round_step(prob, cfg, traced_w=True,
                                       participation=True))
        frozen_ok = True
        for t in range(3):
            keys = jax.random.split(jax.random.PRNGKey(t),
                                    k * n).reshape(k, n, 2)
            w_t = w_fn(jnp.int32(t))
            mask = mask_fn(jnp.int32(t))
            prev = stt
            if impl == "dense":
                stt = step(stt, kb, keys, sparse.densify(w_t), mask)
            else:
                stt = step(stt, kb, keys, w_t, mask)
                inactive = ~np.asarray(mask)
                for name in ("x", "y", "cx", "cy"):
                    np.testing.assert_array_equal(
                        np.asarray(getattr(stt, name))[inactive],
                        np.asarray(getattr(prev, name))[inactive],
                        err_msg=name)
        outs[impl] = stt
    for name in ("x", "y", "cx", "cy"):
        for a, b in zip(jax.tree.leaves(getattr(outs["dense"], name)),
                        jax.tree.leaves(getattr(outs["sparse_packed"], name))):
            np.testing.assert_allclose(a, b, rtol=0, atol=2e-5,
                                       err_msg=f"{family}/{name}")


def test_sparse_packed_rejects_topology_cycle():
    n = 4
    data = make_quadratic_data(jax.random.PRNGKey(0), n, dx=4, dy=2)
    prob = quadratic_problem(data)
    cfg = AlgorithmConfig(num_clients=n, local_steps=2,
                          mixing_impl="sparse_packed",
                          topology_cycle=("ring", "full"))
    with pytest.raises(ValueError, match="not supported with topology_cycle"):
        make_round_step(prob, cfg)


# ---------------------------------------------------------------------------
# the dense-materialization guard (regression: silent O(n²) at scale)
# ---------------------------------------------------------------------------

def test_dense_sampler_refuses_past_materialization_limit():
    n = stoch.DENSE_MATERIALIZATION_LIMIT + 1
    w_fn = stoch.make_w_sampler("erdos_renyi", n, jax.random.PRNGKey(0),
                                edge_prob=0.5)
    with pytest.raises(ValueError,
                       match=r"would materialize a dense \(513, 513\) mixing "
                             r"matrix \(limit 512\)"):
        w_fn(jnp.int32(0))


def test_masked_w_refuses_past_materialization_limit():
    n = 600
    with pytest.raises(ValueError, match="mixing_impl='sparse_packed'"):
        stoch.masked_w(jnp.eye(n), jnp.ones(n, bool))


def test_sparse_full_and_star_refuse_past_limit():
    """The sparse 'twins' of the dense topologies are only dense in
    disguise — they must refuse at the same threshold."""
    for ctor in (sparse.sparse_full, sparse.sparse_star):
        with pytest.raises(ValueError, match="would materialize"):
            ctor(stoch.DENSE_MATERIALIZATION_LIMIT + 1)
    # sparse families stay available past the limit
    assert sparse.sparse_exp(1024).max_degree < 32


def test_guard_threshold_is_inclusive():
    """Exactly at the limit still works (the guard is strictly greater)."""
    n = stoch.DENSE_MATERIALIZATION_LIMIT
    stoch.check_dense_materialization(n, "test")  # no raise
    with pytest.raises(ValueError):
        stoch.check_dense_materialization(n + 1, "test")
