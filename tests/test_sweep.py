"""Tests of the repro.sweep subsystem.

The load-bearing claim mirrors the engine's: batching is an *execution
model* change only.  Every cell of a grid run through ``sweep.batched``
(one vmapped scan program per static cell) produces bit-identical
trajectories, histories, and rounds-to-ε decisions to the corresponding
single-trajectory sequential runs (``run_point``, what
``benchmarks.common.run_to_epsilon`` delegates to), including the
early-stop mask freezing a converged trajectory at exactly the boundary
the sequential ``stop_fn`` would have stopped while the rest of the batch
keeps scanning.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as engine_lib
from repro.sweep import batched as batched_lib
from repro.sweep import defs, grid
from repro.sweep import run as sweep_run
from repro.sweep import store as store_lib

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# grid: points, derive, static-cell partitioning
# ---------------------------------------------------------------------------

def test_points_order_and_derive():
    spec = grid.GridSpec(
        name="t", base=dict(sigma=1.0),
        axes=(grid.static_axis("K", 1, 2), grid.batch_axis("seed", 0, 1)),
        derive=lambda p: {"eta_cx": 0.02 / p["K"]},
    )
    pts = spec.points()
    assert [(p["K"], p["seed"]) for p in pts] == [(1, 0), (1, 1), (2, 0), (2, 1)]
    assert pts[0]["eta_cx"] == 0.02 and pts[2]["eta_cx"] == 0.01
    assert all(p["sigma"] == 1.0 for p in pts)


def test_cells_partition_static_and_cell_key():
    spec = grid.GridSpec(
        name="t",
        axes=(grid.static_axis("algorithm", "kgt_minimax", "local_sgda"),
              grid.batch_axis("sigma", 0.0, 0.5, 1.0,
                              cell_key=lambda s: s > 0),
              grid.batch_axis("seed", 0, 1)),
    )
    cells = spec.cells()
    # 2 algorithms x {sigma==0, sigma>0} = 4 cells covering all 12 points
    assert len(cells) == 4
    assert sum(len(c.points) for c in cells) == 12
    noisy = [c for c in cells if c.static["sigma"] is True]
    assert all(len(c.points) == 4 for c in noisy)  # 2 sigmas x 2 seeds
    for c in cells:
        assert len({p["algorithm"] for p in c.points}) == 1
        assert len({p["sigma"] > 0 for p in c.points}) == 1
    # deterministic keys, order-stable points
    assert cells[0].key == "algorithm=kgt_minimax,sigma=False"


def test_run_cell_rejects_mixed_static_params():
    spec = grid.GridSpec(
        name="t", base=dict(max_rounds=10, eval_every=5),
        axes=(grid.batch_axis("K", 1, 2),),  # K is NOT batchable
    )
    [cell] = spec.cells()
    with pytest.raises(ValueError, match="static program parameters"):
        sweep_run.run_cell(cell)


def test_run_cell_rejects_sigma_span_without_cell_key():
    spec = grid.GridSpec(
        name="t", base=dict(max_rounds=10, eval_every=5),
        axes=(grid.batch_axis("sigma", 0.0, 0.5),),
    )
    [cell] = spec.cells()
    with pytest.raises(ValueError, match="sigma"):
        sweep_run.run_cell(cell)


def test_run_cell_rejects_participation_span_without_cell_key():
    """participation < 1 toggles the mask ops in the graph, exactly like
    sigma > 0 toggles the noise ops: an axis spanning 1.0 must cell-split."""
    spec = grid.GridSpec(
        name="t", base=dict(max_rounds=10, eval_every=5),
        axes=(grid.batch_axis("participation", 1.0, 0.5),),
    )
    [cell] = spec.cells()
    with pytest.raises(ValueError, match="participation"):
        sweep_run.run_cell(cell)


def test_unknown_point_parameter_rejected():
    with pytest.raises(ValueError, match="unknown point parameters"):
        sweep_run.run_point({"nope": 1})


# ---------------------------------------------------------------------------
# batched-vs-sequential bit-identity
# ---------------------------------------------------------------------------

def _assert_cells_bitmatch(spec):
    """Every point of every cell: batched == sequential, bit for bit."""
    for cell in spec.cells():
        results, timing = sweep_run.run_cell(cell)
        assert timing["run_s"] >= 0.0
        for p, rec in zip(cell.points, results):
            hit, final, _, hist = sweep_run.run_point(p)
            ctx = grid.point_key(p)
            assert rec["rounds_to_eps"] == hit, ctx
            # exact float equality: same compiled trajectory program
            assert rec["final_grad"] == final, ctx
            assert rec["history"] == [(r, g) for r, g in hist], ctx


def test_bitmatch_v2_style_grid():
    # V2 shape: K static (changes the local-steps scan), seeds batched,
    # noisy cell, eta derived 1/K — no early stop at this budget.
    spec = grid.GridSpec(
        name="t_v2",
        base=dict(n=4, sigma=2.0, heterogeneity=1.0, eps=0.05, eta_s=0.5,
                  max_rounds=30, eval_every=10),
        axes=(grid.static_axis("K", 1, 2), grid.batch_axis("seed", 0, 1)),
        derive=lambda p: {"eta_cx": 0.02 / p["K"], "eta_cy": 0.2 / p["K"]},
    )
    _assert_cells_bitmatch(spec)


def test_bitmatch_v3_style_grid_noise_free():
    # V3 shape: algorithm static (tracking vs not — different epilogues),
    # heterogeneity rides the batch axis (it only shapes the data arrays),
    # sigma == 0 covers the noise-free cell problem.
    spec = grid.GridSpec(
        name="t_v3",
        base=dict(n=4, K=4, sigma=0.0, eps=0.05, eta_cx=0.01, eta_cy=0.1,
                  max_rounds=30, eval_every=10),
        axes=(grid.static_axis("algorithm", "kgt_minimax", "local_sgda"),
              grid.batch_axis("heterogeneity", 0.0, 2.0)),
        derive=lambda p: {
            "eta_s": 0.5 if p["algorithm"] == "kgt_minimax" else 1.0},
    )
    _assert_cells_bitmatch(spec)


def test_bitmatch_sigma_split_cells():
    spec = grid.GridSpec(
        name="t_sig",
        base=dict(n=4, K=2, heterogeneity=1.0, eps=0.05, eta_cx=0.02,
                  eta_cy=0.2, eta_s=0.5, max_rounds=20, eval_every=10),
        axes=(grid.batch_axis("sigma", 0.0, 0.5, cell_key=lambda s: s > 0),
              grid.batch_axis("seed", 0, 1)),
    )
    assert len(spec.cells()) == 2
    _assert_cells_bitmatch(spec)


def test_bitmatch_packed_mixing_cell():
    # the pallas_packed whole-state epilogue under vmap + traced etas
    spec = grid.GridSpec(
        name="t_packed",
        base=dict(n=4, K=2, sigma=0.3, heterogeneity=1.5, eps=0.05,
                  eta_cx=0.02, eta_cy=0.2, eta_s=0.5, max_rounds=20,
                  eval_every=10, mixing_impl="pallas_packed",
                  topology="full"),
        axes=(grid.batch_axis("seed", 0, 1),),
    )
    _assert_cells_bitmatch(spec)


def test_bitmatch_churn_cells():
    """Acceptance for the churn tentpole: the vmapped cell and the
    sequential reference agree bit-for-bit when every round draws a random
    W (topology family static-split) and a participation mask, with the
    edge probability and participation rate riding the batch axes as
    traced leaves."""
    spec = grid.GridSpec(
        name="t_churn",
        base=dict(n=4, K=2, sigma=0.0, heterogeneity=1.0, eps=0.05,
                  eta_cx=0.02, eta_cy=0.2, eta_s=0.5, max_rounds=20,
                  eval_every=10, topology="full", seed=0),
        axes=(grid.static_axis("topology_family", "erdos_renyi", "dropout"),
              grid.batch_axis("edge_prob", 0.3, 0.8),
              grid.batch_axis("participation", 1.0, 0.6,
                              cell_key=lambda r: r < 1)),
    )
    assert len(spec.cells()) == 4  # 2 families x {mask ops on, off}
    _assert_cells_bitmatch(spec)


def test_bitmatch_pairwise_gossip_cell():
    """The randomized-pairwise family (one random pair per round) through
    the same batched-vs-sequential contract."""
    spec = grid.GridSpec(
        name="t_pair",
        base=dict(n=4, K=2, sigma=0.3, heterogeneity=1.0, eps=0.05,
                  eta_cx=0.02, eta_cy=0.2, eta_s=0.5, max_rounds=20,
                  eval_every=10, topology_family="pairwise"),
        axes=(grid.batch_axis("seed", 0, 1),),
    )
    _assert_cells_bitmatch(spec)


# ---------------------------------------------------------------------------
# early stop: per-trajectory freeze
# ---------------------------------------------------------------------------

def _sequential_state_at_stop(p):
    """Drive the sequential trajectory program to its stop round (the
    run_point loop, keeping the state)."""
    p = sweep_run._full_point(p)
    traj, consts = sweep_run.prepare_trajectory(p)
    build_raw, eval_raw = sweep_run._cell_programs(p, batched=False)
    build = engine_lib.timed_chunk_builder(build_raw)
    eval_fn = sweep_run._timed_eval(eval_raw)
    final_round = jnp.int32(p["max_rounds"] - 1)
    r = 0
    while r < p["max_rounds"]:
        length = min(p["eval_every"], p["max_rounds"] - r)
        traj, _ = build(length)(traj, final_round)
        r += length
        if float(eval_fn(consts, traj.state.x)) < p["eps"]:
            break
    return traj.state, r


def test_early_stop_freezes_at_sequential_round():
    # eps chosen so trajectories converge at *different* boundaries and at
    # least one runs to the budget: the freeze must pin each converged
    # trajectory's state at its own stop round while the batch keeps going.
    base = dict(n=4, K=4, sigma=0.0, eta_cx=0.02, eta_cy=0.2, eta_s=0.7,
                max_rounds=60, eval_every=10, topology="full")
    spec = grid.GridSpec(
        name="t_stop", base=dict(base, eps=0.35),
        axes=(grid.batch_axis("heterogeneity", 0.0, 1.0, 3.0),),
    )
    [cell] = spec.cells()
    (results, timing), trajs = sweep_run.run_cell(cell, return_trajs=True)
    hits = [r["rounds_to_eps"] for r in results]
    assert len(set(hits)) > 1, (
        f"tune eps: all trajectories stopped at the same boundary ({hits})")
    for i, (p, rec) in enumerate(zip(cell.points, results)):
        seq_state, seq_r = _sequential_state_at_stop(p)
        expect_hit = seq_r if rec["rounds_to_eps"] is not None else None
        assert rec["rounds_to_eps"] == expect_hit
        # round leaf froze at the stop boundary...
        assert int(batched_lib.tree_index(trajs.state, i).round) == seq_r
        # ...and every state leaf matches the sequential stop state bitwise
        for name in ("x", "y", "cx", "cy"):
            a = np.asarray(getattr(seq_state, name))
            b = np.asarray(getattr(batched_lib.tree_index(trajs.state, i), name))
            np.testing.assert_array_equal(a, b, err_msg=f"traj {i} {name}")


# ---------------------------------------------------------------------------
# store: merge-don't-clobber + provenance
# ---------------------------------------------------------------------------

def test_store_merge_and_provenance(tmp_path):
    d = str(tmp_path)
    store_lib.save("t", {"points": {"a": {"final_grad": 1.0}},
                         "cells": {"c1": {"B": 2}}}, directory=d)
    store_lib.save("t", {"points": {"b": {"final_grad": np.float32(2.0)}},
                         "cells": {}}, directory=d)
    out = store_lib.load("t", directory=d)
    assert set(out["points"]) == {"a", "b"}
    assert out["cells"]["c1"]["B"] == 2
    assert isinstance(out["points"]["b"]["final_grad"], float)
    prov = out["provenance"]
    for key in ("timestamp", "jax", "device", "git_commit"):
        assert key in prov
    # spec provenance carries the grid + its hash
    spec = defs.SWEEPS["smoke"]
    store_lib.save("t", {"points": {}, "cells": {}}, spec, directory=d)
    prov = store_lib.load("t", directory=d)["provenance"]
    assert prov["grid"]["name"] == "smoke"
    assert len(prov["config_hash"]) == 12


def test_run_sweep_persists_and_merges(tmp_path):
    spec = grid.GridSpec(
        name="t_tiny",
        base=dict(n=4, K=2, sigma=0.5, heterogeneity=1.0, eps=0.5,
                  eta_cx=0.02, eta_cy=0.2, eta_s=0.5, max_rounds=10,
                  eval_every=5),
        axes=(grid.batch_axis("seed", 0, 1),),
    )
    out = sweep_run.run_sweep(spec, store_dir=str(tmp_path))
    stored = store_lib.load("t_tiny", directory=str(tmp_path))
    assert set(stored["points"]) == set(out["points"])
    rec = next(iter(stored["points"].values()))
    assert {"params", "cell", "rounds_to_eps", "final_grad",
            "history"} <= set(rec)
    # second run with an extra seed merges, keeps the old points
    spec2 = grid.GridSpec(name="t_tiny", base=spec.base,
                          axes=(grid.batch_axis("seed", 2),))
    sweep_run.run_sweep(spec2, store_dir=str(tmp_path))
    stored = store_lib.load("t_tiny", directory=str(tmp_path))
    assert len(stored["points"]) == 3


# ---------------------------------------------------------------------------
# timing split (satellite): run_point / engine.run stamps
# ---------------------------------------------------------------------------

def test_run_point_timing_split():
    hit, final, timing, hist = sweep_run.run_point(
        dict(n=4, K=2, sigma=0.5, max_rounds=10, eval_every=5, eps=0.0))
    assert set(timing) == {"wall_s", "compile_s", "setup_s", "run_s"}
    assert timing["compile_s"] > 0.0
    assert timing["run_s"] >= 0.0
    # ms-grained rounding discipline on every stamp (satellite: run_s used
    # to be raw and unclamped)
    for key, value in timing.items():
        assert value == round(value, 3), (key, value)
    assert timing["wall_s"] == pytest.approx(
        timing["compile_s"] + timing["setup_s"] + timing["run_s"], abs=2e-3)
    assert hist[-1][0] == 10 and hit is None


def test_timed_chunk_builder_splits_compile():
    calls = []

    def fake_build(length):
        return jax.jit(lambda s, f: (s + length, None))

    build = engine_lib.timed_chunk_builder(fake_build)
    fn = build(3)
    out, _ = fn(jnp.float32(1.0), jnp.int32(0))
    c1 = build.stats["compile_s"]
    assert c1 > 0.0
    out, _ = fn(out, jnp.int32(0))
    assert build.stats["compile_s"] == c1  # steady state: no recompiles
    assert float(out) == 7.0
    assert build(3) is fn  # per-length cache


def test_engine_run_records_carry_split_stamps():
    metrics = lambda st, b: {"v": jnp.float32(0.0)}
    sampler = lambda r: (jnp.zeros(()), jnp.zeros((2,), jnp.uint32))

    import dataclasses as dc

    @jax.tree_util.register_dataclass
    @dc.dataclass
    class S:
        round: jnp.ndarray

    step = lambda st, b, k: S(round=st.round + 1)
    build = engine_lib.make_chunk_builder(step, sampler, metrics, donate=False)
    state, history = engine_lib.run(
        S(round=jnp.int32(0)), build, total_rounds=4, chunk_rounds=2)
    assert len(history) == 4
    for rec in history:
        assert {"wall_s", "compile_s", "run_s"} <= set(rec)
        assert rec["run_s"] <= rec["wall_s"]
    # a second run with the SAME builder reuses the compiled chunks: no
    # recompilation billed to it, and run_s stays non-negative
    state, history = engine_lib.run(
        state, build, total_rounds=8, chunk_rounds=2)
    for rec in history:
        assert rec["compile_s"] == 0.0
        assert rec["run_s"] >= 0.0


# ---------------------------------------------------------------------------
# defs sanity + benchmark row helpers
# ---------------------------------------------------------------------------

def test_grid_dedup_drops_coinciding_points():
    spec = grid.GridSpec(
        name="t_dd",
        axes=(grid.static_axis("fam", "a", "b"),
              grid.batch_axis("p", 0.3, 0.7)),
        derive=lambda pt: {} if pt["fam"] == "a" else {"p": 0.5},
        dedup=True,
    )
    pts = spec.points()
    # fam=a keeps both p values; fam=b collapses to the single pinned point
    assert [(p["fam"], p["p"]) for p in pts] == [
        ("a", 0.3), ("a", 0.7), ("b", 0.5)]


def test_paper_sweep_defs_partition_as_documented():
    expected_cells = {
        "local_steps": 5,      # K static
        "heterogeneity": 2,    # algorithm static; het+seed batched
        "topology": 4,
        "speedup": 4,          # n static
        "convergence": 4,      # algorithm static, 8 seeds batched
        "churn": 8,            # family static x participation cell split
        "adversary": 6,        # mixing_impl static x byzantine cell split
        "smoke": 1,
    }
    for name, n_cells in expected_cells.items():
        spec = defs.SWEEPS[name]
        cells = spec.cells()
        assert len(cells) == n_cells, name
        # every cell passes the static-uniformity validation
        for cell in cells:
            pts = [sweep_run._full_point(p) for p in cell.points]
            for k in sweep_run.STATIC_KEYS:
                assert len({p[k] for p in pts}) == 1, (name, cell.key, k)
    assert len(defs.SWEEPS["convergence"].points()) == 32
    # churn: edge_prob only varies the erdos_renyi family (8 points); the
    # other three families dedup to participation x seed (4 each)
    assert len(defs.SWEEPS["churn"].points()) == 8 + 3 * 4
    # adversary: the attack axis only varies the attacked regime (f=0 pins
    # attack="honest"), so 3 impls x (3 attacks x 2 seeds + 2 honest seeds)
    assert len(defs.SWEEPS["adversary"].points()) == 3 * (3 * 2 + 2)


def test_replicate_row_helpers():
    from benchmarks.common import replicate_row, seed0_point

    result = {"points": {
        "a": {"params": {"K": 1, "seed": 0}, "rounds_to_eps": 10,
              "final_grad": 0.5},
        "b": {"params": {"K": 1, "seed": 1}, "rounds_to_eps": None,
              "final_grad": 0.7},
        "c": {"params": {"K": 2, "seed": 0}, "rounds_to_eps": 20,
              "final_grad": 0.1},
    }}
    assert seed0_point(result, K=2)["rounds_to_eps"] == 20
    row = replicate_row(result, K=1)
    assert row["rounds_to_eps"] == 10 and row["num"] == 2
    assert row["final_grad_mean"] == pytest.approx(0.6)
    assert row["hit_rate"] == 0.5
    assert row["rounds_to_eps_mean"] == 10.0


def test_churn_static_baseline_selected_structurally():
    """Regression for the bench_churn headline lookup: the static baseline
    must be found by its fields, not by a hard-coded "static@1.0" label —
    labels embed edge_prob whenever a family carries more than one."""
    from benchmarks.bench_churn import static_baseline

    rows = {
        "static(edge_prob=0.3)@0.7": {"topology_family": "static",
                                      "participation": 0.7,
                                      "final_grad_mean": 0.5},
        "static(edge_prob=0.3)@1.0": {"topology_family": "static",
                                      "participation": 1.0,
                                      "final_grad_mean": 0.2},
        "erdos_renyi@1.0": {"topology_family": "erdos_renyi",
                            "participation": 1.0, "final_grad_mean": 0.3},
        "_summary": {"worst_final_mean": 0.5},
    }
    assert static_baseline(rows)["final_grad_mean"] == 0.2
    with pytest.raises(KeyError, match="static"):
        static_baseline({"_summary": {}})


# ---------------------------------------------------------------------------
# batch-axis GSPMD sharding (subprocess: XLA flag must precede jax init)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro.launch import mesh as mesh_lib
from repro.sweep import grid
from repro.sweep import run as sweep_run

mesh = mesh_lib.fake_mesh(2, 2, 1)
spec = grid.GridSpec(
    name="t_mesh",
    base=dict(n=4, K=2, sigma=0.5, heterogeneity=1.0, eps=0.0,
              eta_cx=0.02, eta_cy=0.2, eta_s=0.5, max_rounds=10,
              eval_every=5),
    axes=(grid.batch_axis("seed", 0, 1, 2, 3),),
)
[cell] = spec.cells()
sharded, _ = sweep_run.run_cell(cell, mesh=mesh)
plain, _ = sweep_run.run_cell(cell)
for a, b in zip(sharded, plain):
    assert a["history"] == b["history"], (a, b)
print("MESH_SWEEP_OK")
"""


@pytest.mark.slow
def test_batch_axis_sharded_cell_matches_unsharded():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT], env=env,
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    assert "MESH_SWEEP_OK" in proc.stdout
