"""End-to-end system tests: the full training driver on reduced models."""
import argparse

import jax.numpy as jnp
import pytest

from repro.launch import train as train_lib


def _args(**over):
    base = dict(
        arch="qwen2-0.5b", reduced=True, algorithm="kgt_minimax", rounds=6,
        clients=2, local_steps=2, batch=2, seq_len=32, groups=4, mu=1.0,
        alpha=0.3, eta_cx=0.02, eta_cy=0.2, eta_s=0.7, topology="ring",
        mixing_impl="dense", gossip_dtype="float32", schedule="constant",
        warmup=0, seed=0, log_every=2, checkpoint_every=0,
        checkpoint_dir="/tmp/repro_test_ckpt", out=None,
    )
    base.update(over)
    return argparse.Namespace(**base)


def test_scan_engine_history_matches_host_engine():
    """--engine scan and --engine host share the sampler and metrics fn;
    the logged histories must agree record for record (the state
    trajectories are bit-identical — tests/test_engine.py)."""
    res_scan = train_lib.train(_args(rounds=4, engine="scan", chunk=3))
    res_host = train_lib.train(_args(rounds=4, engine="host"))
    hs, hh = res_scan["history"], res_host["history"]
    assert [r["round"] for r in hs] == [r["round"] for r in hh] == [0, 2, 3]
    for rs, rh in zip(hs, hh):
        for key in ("f_bar", "mean_loss", "eval_loss", "consensus_x",
                    "y_bar_norm", "corr_x_norm", "corr_y_norm"):
            assert rs[key] == pytest.approx(rh[key], rel=1e-5, abs=1e-7), key


def test_train_rounds_zero_no_history():
    """--rounds 0 / a log grid that never fires must not crash on
    history[-1]."""
    res = train_lib.train(_args(rounds=0))
    assert res["history"] == []
    assert res["final_consensus"] is None


def test_train_driver_end_to_end():
    res = train_lib.train(_args())
    hist = res["history"]
    assert len(hist) >= 2
    assert all(jnp.isfinite(h["f_bar"]) for h in hist)
    assert res["final_consensus"] < 1.0


def test_train_driver_loss_improves():
    res = train_lib.train(_args(rounds=20, eta_cx=0.05, eta_cy=0.2, batch=4))
    hist = res["history"]
    # the LM quality metric (mean group loss) must improve; the saddle value
    # f(x̄,ȳ) itself is not monotone (y climbs first)
    assert hist[-1]["mean_loss"] < hist[0]["mean_loss"]


@pytest.mark.parametrize("algorithm", ["dsgda", "local_sgda", "gt_gda"])
def test_train_driver_baselines(algorithm):
    res = train_lib.train(_args(algorithm=algorithm, rounds=4))
    assert all(jnp.isfinite(h["f_bar"]) for h in res["history"])


def test_train_driver_checkpointing(tmp_path):
    train_lib.train(_args(rounds=4, checkpoint_every=2,
                          checkpoint_dir=str(tmp_path)))
    from repro.checkpoint import latest
    assert latest(str(tmp_path)) is not None


def test_scan_engine_honors_checkpoint_cadence(tmp_path):
    """checkpoint_every finer than the chunk must shrink the chunk, not
    silently skip multiples (scan engine saves at chunk boundaries)."""
    import os

    train_lib.train(_args(rounds=6, engine="scan", chunk=16,
                          checkpoint_every=2, checkpoint_dir=str(tmp_path)))
    names = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert names == ["round_000002.npz", "round_000004.npz",
                     "round_000006.npz"]


def test_train_driver_wsd_schedule():
    res = train_lib.train(_args(rounds=6, schedule="wsd", warmup=2,
                                arch="minicpm-2b"))
    assert all(jnp.isfinite(h["f_bar"]) for h in res["history"])
