"""End-to-end system tests: the full training driver on reduced models."""
import argparse

import jax.numpy as jnp
import pytest

from repro.launch import train as train_lib


def _args(**over):
    base = dict(
        arch="qwen2-0.5b", reduced=True, algorithm="kgt_minimax", rounds=6,
        clients=2, local_steps=2, batch=2, seq_len=32, groups=4, mu=1.0,
        alpha=0.3, eta_cx=0.02, eta_cy=0.2, eta_s=0.7, topology="ring",
        mixing_impl="dense", gossip_dtype="float32", schedule="constant",
        warmup=0, seed=0, log_every=2, checkpoint_every=0,
        checkpoint_dir="/tmp/repro_test_ckpt", out=None,
    )
    base.update(over)
    return argparse.Namespace(**base)


def test_train_driver_end_to_end():
    res = train_lib.train(_args())
    hist = res["history"]
    assert len(hist) >= 2
    assert all(jnp.isfinite(h["f_bar"]) for h in hist)
    assert res["final_consensus"] < 1.0


def test_train_driver_loss_improves():
    res = train_lib.train(_args(rounds=20, eta_cx=0.05, eta_cy=0.2, batch=4))
    hist = res["history"]
    # the LM quality metric (mean group loss) must improve; the saddle value
    # f(x̄,ȳ) itself is not monotone (y climbs first)
    assert hist[-1]["mean_loss"] < hist[0]["mean_loss"]


@pytest.mark.parametrize("algorithm", ["dsgda", "local_sgda", "gt_gda"])
def test_train_driver_baselines(algorithm):
    res = train_lib.train(_args(algorithm=algorithm, rounds=4))
    assert all(jnp.isfinite(h["f_bar"]) for h in res["history"])


def test_train_driver_checkpointing(tmp_path):
    train_lib.train(_args(rounds=4, checkpoint_every=2,
                          checkpoint_dir=str(tmp_path)))
    from repro.checkpoint import latest
    assert latest(str(tmp_path)) is not None


def test_train_driver_wsd_schedule():
    res = train_lib.train(_args(rounds=6, schedule="wsd", warmup=2,
                                arch="minicpm-2b"))
    assert all(jnp.isfinite(h["f_bar"]) for h in res["history"])
