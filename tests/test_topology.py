import numpy as np
import pytest

# _hyp resolves to real hypothesis when installed, else the bundled
# fallback — the contraction property below runs either way (no skips).
from _hyp import given, settings, st

from repro.core import topology


@pytest.mark.parametrize("name", ["ring", "full", "exp", "star"])
@pytest.mark.parametrize("n", [1, 2, 3, 4, 8, 13])
def test_mixing_matrix_valid(name, n):
    w = topology.mixing_matrix(name, n)
    assert w.shape == (n, n)
    assert np.allclose(w, w.T)
    assert np.allclose(w.sum(1), 1.0)
    assert (w >= -1e-12).all()


@pytest.mark.parametrize("n", [4, 9, 16])
def test_torus_valid(n):
    w = topology.mixing_matrix("torus", n)
    assert np.allclose(w, w.T) and np.allclose(w.sum(1), 1.0)


@pytest.mark.parametrize("n", [2, 3, 6, 8, 15])
def test_torus_rejects_nonsquare_with_clear_error(n):
    """The constructor must fail loudly (not silently skip) on non-square
    client counts — callers parametrize square n explicitly instead."""
    with pytest.raises(ValueError, match=f"torus needs a square n, got {n}"):
        topology.mixing_matrix("torus", n)


def test_spectral_gap_ordering():
    """full > exp > torus > ring for largish n (connectivity ordering)."""
    n = 16
    gaps = {k: topology.spectral_gap(topology.mixing_matrix(k, n))
            for k in ("ring", "torus", "exp", "full")}
    assert gaps["full"] == pytest.approx(1.0)
    assert gaps["full"] > gaps["exp"] > gaps["torus"] > gaps["ring"] > 0


@given(n=st.integers(2, 24),
       name=st.sampled_from(["ring", "full", "exp", "star"]))
@settings(max_examples=40, deadline=None)
def test_contraction_property(n, name):
    """Assumption 4: ||XW - X̄||_F^2 <= (1-p) ||X - X̄||_F^2 for random X."""
    w = topology.mixing_matrix(name, n)
    p = topology.spectral_gap(w)
    assert 0 <= p <= 1 + 1e-9
    rng = np.random.default_rng(n)
    x = rng.normal(size=(7, n))
    xbar = x.mean(1, keepdims=True)
    lhs = np.linalg.norm(x @ w - xbar) ** 2
    rhs = (1 - p) * np.linalg.norm(x - xbar) ** 2
    assert lhs <= rhs + 1e-8 * max(1.0, rhs)
